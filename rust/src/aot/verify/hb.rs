//! Independent happens-before closure over a replay tape.
//!
//! This is deliberately **not** built on [`crate::graph::Dag`] or
//! [`crate::graph::reach::Reachability`]: those power the optimizer
//! (`aot::memory::lifetime`) whose output the verifier audits, so the
//! verifier recomputes ordering from the tape alone — ancestor bitsets
//! propagated in Kahn order over raw adjacency lists, where the
//! optimizer computes descendant bitsets in reverse topological order
//! over a `Dag`. N-versioning the two implementations means a bug in
//! either one surfaces as a diagnostic instead of a shared blind spot.
//!
//! The relation is the executor's real ordering guarantee: within one
//! stream, records run in tape order (the per-stream worker is a FIFO);
//! across streams, a record that waits on event `e` runs after the
//! record that records `e` (the runtime event table releases waiters at
//! the *first* record of an event, so a multiply-recorded event
//! contributes only its first recorder here — later recorders are
//! reported separately as diagnostics).

use crate::aot::tape::ReplayTape;

/// Strict happens-before relation over tape records (indices into
/// [`ReplayTape::ops`]), with a topological order and, when the edge
/// set is cyclic, one concrete cyclic chain as a deadlock witness.
pub struct HbClosure {
    n: usize,
    words: usize,
    /// Row `v`: bit `u` set ⇔ `u` strictly happens-before `v`.
    /// Rows are only populated for records reached by the topological
    /// order, i.e. all of them when [`cycle`](Self::cycle) is `None`.
    anc: Vec<u64>,
    /// Kahn topological order (covers all records iff acyclic).
    pub order: Vec<u32>,
    /// A cyclic wait/record chain if one exists, in edge order:
    /// `cycle[i]` has an HB edge to `cycle[i+1]`, the last wraps to the
    /// first. Every record on it waits (transitively) on itself.
    pub cycle: Option<Vec<u32>>,
    /// Deduplicated HB edge count (program order ∪ record→wait).
    pub n_edges: usize,
}

impl HbClosure {
    pub fn n_ops(&self) -> usize {
        self.n
    }

    /// Does record `u` strictly happen before record `v`?
    pub fn happens_before(&self, u: usize, v: usize) -> bool {
        debug_assert!(u < self.n && v < self.n);
        (self.anc[v * self.words + u / 64] >> (u % 64)) & 1 == 1
    }

    /// Are `u` and `v` ordered (either direction) under happens-before?
    pub fn ordered(&self, u: usize, v: usize) -> bool {
        u == v || self.happens_before(u, v) || self.happens_before(v, u)
    }

    pub fn is_acyclic(&self) -> bool {
        self.cycle.is_none()
    }

    /// Topologically ordered strict HB-predecessors of `x` ∪ `y`: a
    /// legal schedule prefix after which `x` and `y` are both eligible
    /// simultaneously — the witness interleaving for an unordered pair.
    pub fn joint_prefix(&self, x: usize, y: usize) -> Vec<u32> {
        self.order
            .iter()
            .copied()
            .filter(|&p| {
                self.happens_before(p as usize, x) || self.happens_before(p as usize, y)
            })
            .collect()
    }
}

/// Build the happens-before closure of a tape. Event indices out of
/// range and events nothing records are *skipped* here (they contribute
/// no edges); the caller reports those as well-formedness diagnostics
/// before trusting the closure.
pub fn closure(tape: &ReplayTape) -> HbClosure {
    let n = tape.n_ops();
    let words = n.div_ceil(64).max(1);
    let mut preds: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut succs: Vec<Vec<u32>> = vec![Vec::new(); n];

    // Program order: consecutive records of one stream's tape.
    for s in 0..tape.n_streams() {
        for w in tape.stream_ops(s).windows(2) {
            preds[w[1] as usize].push(w[0]);
            succs[w[0] as usize].push(w[1]);
        }
    }
    // Event edges: first recorder of `e` → every record waiting on `e`.
    let mut recorder = vec![u32::MAX; tape.n_events()];
    for (i, op) in tape.ops().iter().enumerate() {
        for &e in tape.records(op) {
            if let Some(r) = recorder.get_mut(e as usize) {
                if *r == u32::MAX {
                    *r = i as u32;
                }
            }
        }
    }
    for (i, op) in tape.ops().iter().enumerate() {
        for &e in tape.waits(op) {
            if let Some(&r) = recorder.get(e as usize) {
                if r != u32::MAX {
                    // r == i (waiting on your own record) is kept as a
                    // self-loop: Kahn never drains it, so it is reported
                    // as a one-record cycle — which is exactly what it
                    // is at replay time (the wait can never be released
                    // before the record fires).
                    preds[i].push(r);
                    succs[r as usize].push(i as u32);
                }
            }
        }
    }
    let mut n_edges = 0usize;
    for v in 0..n {
        preds[v].sort_unstable();
        preds[v].dedup();
        succs[v].sort_unstable();
        succs[v].dedup();
        n_edges += preds[v].len();
    }

    // Kahn's algorithm, frontier drained in submission-index order so
    // `order` (and every witness prefix derived from it) is stable.
    let mut indeg: Vec<usize> = preds.iter().map(|p| p.len()).collect();
    let mut frontier: std::collections::BinaryHeap<std::cmp::Reverse<u32>> = (0..n as u32)
        .filter(|&v| indeg[v as usize] == 0)
        .map(std::cmp::Reverse)
        .collect();
    let mut order = Vec::with_capacity(n);
    while let Some(std::cmp::Reverse(u)) = frontier.pop() {
        order.push(u);
        for &v in &succs[u as usize] {
            indeg[v as usize] -= 1;
            if indeg[v as usize] == 0 {
                frontier.push(std::cmp::Reverse(v));
            }
        }
    }

    let cycle = if order.len() < n {
        Some(extract_cycle(n, &order, &preds))
    } else {
        None
    };

    // Ancestor sets, propagated in topological order: by the time `v`
    // is visited every predecessor's row is final.
    let mut anc = vec![0u64; n * words];
    let mut row = vec![0u64; words];
    for &v in &order {
        let v = v as usize;
        row.iter_mut().for_each(|w| *w = 0);
        for &p in &preds[v] {
            let p = p as usize;
            let src = &anc[p * words..(p + 1) * words];
            for (d, s) in row.iter_mut().zip(src) {
                *d |= *s;
            }
            row[p / 64] |= 1u64 << (p % 64);
        }
        anc[v * words..(v + 1) * words].copy_from_slice(&row);
    }

    HbClosure { n, words, anc, order, cycle, n_edges }
}

/// One concrete cycle among the records Kahn could not drain. Every
/// undrained record keeps at least one undrained predecessor, so
/// walking predecessors inside that set must revisit a record; the
/// slice between the two visits, reversed, is a cycle in edge order.
fn extract_cycle(n: usize, order: &[u32], preds: &[Vec<u32>]) -> Vec<u32> {
    let mut remaining = vec![true; n];
    for &v in order {
        remaining[v as usize] = false;
    }
    let start = (0..n).find(|&v| remaining[v]).expect("cycle exists");
    let mut seen = vec![usize::MAX; n];
    let mut path = vec![start as u32];
    seen[start] = 0;
    loop {
        let cur = *path.last().expect("non-empty") as usize;
        let p = *preds[cur]
            .iter()
            .find(|&&p| remaining[p as usize])
            .expect("undrained record has an undrained predecessor") as usize;
        if seen[p] != usize::MAX {
            let mut cycle: Vec<u32> = path[seen[p]..].to_vec();
            cycle.reverse();
            return cycle;
        }
        seen[p] = path.len();
        path.push(p as u32);
    }
}
