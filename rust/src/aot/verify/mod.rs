//! Static plan verification: certify a compiled [`ReplayTape`] (and
//! optionally the [`ArenaPlan`] laying its slots out in shared bytes)
//! race-free, deadlock-free, and alias-sound *before* anything runs.
//!
//! Nimble's premise is that the whole execution schedule — tape order,
//! record→wait sync edges, arena byte layout — is a static artifact, so
//! its correctness is decidable ahead of time. This module is that
//! decision procedure. It rebuilds the happens-before relation from the
//! tape alone ([`hb`], independent of the optimizer's reachability code
//! in `aot::memory::lifetime` precisely so it can audit it) and checks:
//!
//! * **well-formedness** — slot/arg/event indices in bounds, no
//!   self-dependencies, unique slot writers, unique event recorders,
//!   the output slot reachable from the inputs;
//! * **deadlock-freedom** — no wait on an event nothing records
//!   ([`DiagKind::OrphanWait`]), no cyclic wait/record chain
//!   ([`DiagKind::HbCycle`], reported with the concrete cycle);
//! * **race-freedom** — every slot access pair (its writer vs. each
//!   reader) ordered under happens-before, else a [`DiagKind::Race`]
//!   with a two-op witness interleaving: a legal schedule prefix after
//!   which both records are simultaneously eligible;
//! * **arena-aliasing soundness** — every byte-overlapping slot pair in
//!   the arena plan has happens-before-ordered disjoint lifetimes (one
//!   slot's last access strictly precedes the other's definition), else
//!   [`DiagKind::AliasOverlap`] with the guilty access pair.
//!
//! [`verify`] checks the tape alone; [`verify_with_arena`] adds the
//! aliasing pass. Both run at build time only — the replay hot path is
//! untouched ([`VerifyMode`] documents the builder policy knob).
//! Reports render as a diagnostic table ([`VerifyReport::render`]) and
//! as machine-readable JSON ([`VerifyReport::to_json`]); `nimble
//! verify <model>` exposes both on the CLI. The analyzer self-tests
//! against the seeded plan mutator in [`mutate`].

pub mod hb;
pub mod mutate;

use crate::aot::memory::ArenaPlan;
use crate::aot::tape::{ReplayTape, TapeArg, TapeRole};
use crate::util::json::push_escaped;
use crate::util::table::Table;
use std::fmt::Write as _;

/// Build-time verification policy for engine builders.
///
/// * `Strict` — refuse to build on **any** diagnostic.
/// * `Warn` — print the report to stderr and build anyway.
/// * `Off` — skip verification.
///
/// The default is `Warn` in debug builds and `Off` in release builds;
/// verification always runs at build time only, so even `Strict` adds
/// nothing to the replay hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VerifyMode {
    Off,
    Warn,
    Strict,
}

impl Default for VerifyMode {
    fn default() -> Self {
        if cfg!(debug_assertions) {
            VerifyMode::Warn
        } else {
            VerifyMode::Off
        }
    }
}

/// The diagnostic catalog.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DiagKind {
    /// A slot, argument, or event index out of bounds, or a record
    /// whose argument names its own output slot (self-dependency).
    BadIndex,
    /// Two records write the same slot.
    DuplicateWriter,
    /// Two records record the same event; the runtime releases waiters
    /// at the first record, so ordering against later recorders is
    /// illusory.
    DuplicateRecorder,
    /// A wait on an event nothing records: the waiter's stream blocks
    /// forever at replay time.
    OrphanWait,
    /// A cyclic wait/record chain: every record on it transitively
    /// waits on itself, so none can start.
    HbCycle,
    /// A record reads a slot that is never written, or is ordered
    /// before its writer.
    UseBeforeDef,
    /// A slot's writer and one of its readers are unordered under
    /// happens-before: a data race on the slot's bytes.
    Race,
    /// Two slots share arena bytes but neither retires below the other:
    /// aliased bytes with overlapping lifetimes.
    AliasOverlap,
    /// The arena plan is malformed: missing entries, a reservation
    /// smaller than the slot's written extent, or an extent past the
    /// end of the reservation.
    ArenaBounds,
    /// The output slot is not reachable from any input slot through
    /// argument edges: replay produces a result no request data feeds.
    DeadOutput,
}

impl DiagKind {
    pub fn name(self) -> &'static str {
        match self {
            DiagKind::BadIndex => "bad-index",
            DiagKind::DuplicateWriter => "duplicate-writer",
            DiagKind::DuplicateRecorder => "duplicate-recorder",
            DiagKind::OrphanWait => "orphan-wait",
            DiagKind::HbCycle => "hb-cycle",
            DiagKind::UseBeforeDef => "use-before-def",
            DiagKind::Race => "race",
            DiagKind::AliasOverlap => "alias-overlap",
            DiagKind::ArenaBounds => "arena-bounds",
            DiagKind::DeadOutput => "dead-output",
        }
    }
}

/// A concrete interleaving demonstrating an unordered access pair:
/// run exactly `prefix` (a legal schedule order), and both `focus`
/// records are eligible simultaneously.
#[derive(Debug, Clone)]
pub struct Witness {
    /// Tape record indices, in a legal (topological) schedule order.
    pub prefix: Vec<u32>,
    /// The two records left simultaneously eligible after `prefix`.
    pub focus: (u32, u32),
}

/// One verification finding.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub kind: DiagKind,
    /// Tape record indices involved (submission order).
    pub ops: Vec<u32>,
    pub slot: Option<u32>,
    pub event: Option<u32>,
    pub message: String,
    pub witness: Option<Witness>,
}

/// The structured result of a verification run.
#[derive(Debug, Clone)]
pub struct VerifyReport {
    pub diagnostics: Vec<Diagnostic>,
    pub n_ops: usize,
    pub n_streams: usize,
    pub n_slots: usize,
    pub n_events: usize,
    /// Deduplicated happens-before edges (program order ∪ record→wait).
    pub hb_edges: usize,
    /// Byte-overlapping slot pairs the aliasing pass examined (0 when
    /// no arena plan was supplied or earlier diagnostics skipped it).
    pub alias_pairs_checked: usize,
}

impl VerifyReport {
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    pub fn count(&self, kind: DiagKind) -> usize {
        self.diagnostics.iter().filter(|d| d.kind == kind).count()
    }

    pub fn has(&self, kind: DiagKind) -> bool {
        self.diagnostics.iter().any(|d| d.kind == kind)
    }

    /// Human-readable diagnostic table (with witness interleavings),
    /// or a one-line clean summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{} records / {} streams / {} slots / {} events / {} hb edges / {} alias pairs checked",
            self.n_ops,
            self.n_streams,
            self.n_slots,
            self.n_events,
            self.hb_edges,
            self.alias_pairs_checked
        );
        if self.is_clean() {
            let _ = writeln!(out, "CLEAN: no diagnostics");
            return out;
        }
        let _ = writeln!(out, "{} diagnostic(s):", self.diagnostics.len());
        let mut t = Table::new(vec!["#", "kind", "ops", "slot", "event", "message"]);
        for (i, d) in self.diagnostics.iter().enumerate() {
            let ops = d.ops.iter().map(|o| format!("#{o}")).collect::<Vec<_>>().join(",");
            t.row(vec![
                i.to_string(),
                d.kind.name().to_string(),
                ops,
                d.slot.map_or_else(|| "-".into(), |s| s.to_string()),
                d.event.map_or_else(|| "-".into(), |e| e.to_string()),
                d.message.clone(),
            ]);
        }
        out.push_str(&t.render());
        for (i, d) in self.diagnostics.iter().enumerate() {
            if let Some(w) = &d.witness {
                let prefix =
                    w.prefix.iter().map(|o| format!("#{o}")).collect::<Vec<_>>().join(" ");
                let _ = writeln!(
                    out,
                    "witness[{i}]: legal prefix [{prefix}] exposes the pair (#{}, #{})",
                    w.focus.0, w.focus.1
                );
            }
        }
        out
    }

    /// Machine-readable report (stable schema, see README).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{");
        let _ = write!(
            s,
            "\"clean\":{},\"n_ops\":{},\"n_streams\":{},\"n_slots\":{},\"n_events\":{},\
             \"hb_edges\":{},\"alias_pairs_checked\":{},\"diagnostics\":[",
            self.is_clean(),
            self.n_ops,
            self.n_streams,
            self.n_slots,
            self.n_events,
            self.hb_edges,
            self.alias_pairs_checked
        );
        for (i, d) in self.diagnostics.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "{{\"kind\":\"{}\",\"ops\":[", d.kind.name());
            for (j, o) in d.ops.iter().enumerate() {
                if j > 0 {
                    s.push(',');
                }
                let _ = write!(s, "{o}");
            }
            s.push_str("],\"slot\":");
            match d.slot {
                Some(v) => {
                    let _ = write!(s, "{v}");
                }
                None => s.push_str("null"),
            }
            s.push_str(",\"event\":");
            match d.event {
                Some(v) => {
                    let _ = write!(s, "{v}");
                }
                None => s.push_str("null"),
            }
            s.push_str(",\"message\":\"");
            push_escaped(&mut s, &d.message);
            s.push_str("\",\"witness\":");
            match &d.witness {
                Some(w) => {
                    s.push_str("{\"prefix\":[");
                    for (j, o) in w.prefix.iter().enumerate() {
                        if j > 0 {
                            s.push(',');
                        }
                        let _ = write!(s, "{o}");
                    }
                    let _ = write!(s, "],\"focus\":[{},{}]}}", w.focus.0, w.focus.1);
                }
                None => s.push_str("null"),
            }
            s.push('}');
        }
        s.push_str("]}");
        s
    }
}

/// Verify the tape alone (sync + slot-level analysis, no arena).
pub fn verify(tape: &ReplayTape) -> VerifyReport {
    verify_inner(tape, None)
}

/// Verify the tape plus the arena layout that will back its slots.
pub fn verify_with_arena(tape: &ReplayTape, arena: &ArenaPlan) -> VerifyReport {
    verify_inner(tape, Some(arena))
}

/// Slot access structure: the (first) writer record and every reader
/// record of each slot, by tape index. An `Input` record counts as its
/// slot's writer: the bytes are host-filled before replay starts, but
/// the sync plan's contract (and `plan_is_safe`, the legacy oracle) is
/// that consumers order themselves after the input record's events, so
/// the verifier holds plans to the same bar.
struct SlotAccess {
    writer: Vec<Option<u32>>,
    readers: Vec<Vec<u32>>,
}

fn slot_access(tape: &ReplayTape) -> SlotAccess {
    let mut writer: Vec<Option<u32>> = vec![None; tape.n_slots()];
    let mut readers: Vec<Vec<u32>> = vec![Vec::new(); tape.n_slots()];
    for (i, op) in tape.ops().iter().enumerate() {
        if let Some(w) = writer.get_mut(op.out_slot as usize) {
            if w.is_none() {
                *w = Some(i as u32);
            }
        }
        for arg in tape.args(op) {
            if let TapeArg::Slot(s) = arg {
                if let Some(r) = readers.get_mut(*s as usize) {
                    r.push(i as u32);
                }
            }
        }
    }
    SlotAccess { writer, readers }
}

fn verify_inner(tape: &ReplayTape, arena: Option<&ArenaPlan>) -> VerifyReport {
    let mut report = VerifyReport {
        diagnostics: Vec::new(),
        n_ops: tape.n_ops(),
        n_streams: tape.n_streams(),
        n_slots: tape.n_slots(),
        n_events: tape.n_events(),
        hb_edges: 0,
        alias_pairs_checked: 0,
    };
    let diags = &mut report.diagnostics;

    // ---- Pass 1: well-formedness (index bounds, self-deps, unique
    // writers/recorders, orphan waits). Runs before anything trusts the
    // indices.
    let n_slots = tape.n_slots();
    let n_events = tape.n_events();
    let mut bad_index = false;
    for (i, op) in tape.ops().iter().enumerate() {
        let i = i as u32;
        if op.out_slot as usize >= n_slots {
            bad_index = true;
            diags.push(Diagnostic {
                kind: DiagKind::BadIndex,
                ops: vec![i],
                slot: Some(op.out_slot),
                event: None,
                message: format!(
                    "record #{i} writes slot {} but the tape has {n_slots} slots",
                    op.out_slot
                ),
                witness: None,
            });
        }
        for arg in tape.args(op) {
            if let TapeArg::Slot(s) = arg {
                if *s as usize >= n_slots {
                    bad_index = true;
                    diags.push(Diagnostic {
                        kind: DiagKind::BadIndex,
                        ops: vec![i],
                        slot: Some(*s),
                        event: None,
                        message: format!(
                            "record #{i} reads slot {s} but the tape has {n_slots} slots"
                        ),
                        witness: None,
                    });
                } else if *s == op.out_slot {
                    diags.push(Diagnostic {
                        kind: DiagKind::BadIndex,
                        ops: vec![i],
                        slot: Some(*s),
                        event: None,
                        message: format!(
                            "record #{i} reads its own output slot {s}: a self-dependency \
                             can never be satisfied"
                        ),
                        witness: None,
                    });
                }
            }
        }
        for &e in tape.waits(op).iter().chain(tape.records(op)) {
            if e as usize >= n_events {
                bad_index = true;
                diags.push(Diagnostic {
                    kind: DiagKind::BadIndex,
                    ops: vec![i],
                    slot: None,
                    event: Some(e),
                    message: format!(
                        "record #{i} references event {e} but the tape has {n_events} events"
                    ),
                    witness: None,
                });
            }
        }
    }
    if bad_index {
        // Indices are unreliable; every later pass would chase them.
        return report;
    }

    let mut writers_of: Vec<Vec<u32>> = vec![Vec::new(); n_slots];
    let mut recorders_of: Vec<Vec<u32>> = vec![Vec::new(); n_events];
    for (i, op) in tape.ops().iter().enumerate() {
        writers_of[op.out_slot as usize].push(i as u32);
        for &e in tape.records(op) {
            recorders_of[e as usize].push(i as u32);
        }
    }
    for (s, ws) in writers_of.iter().enumerate() {
        if ws.len() > 1 {
            diags.push(Diagnostic {
                kind: DiagKind::DuplicateWriter,
                ops: ws.clone(),
                slot: Some(s as u32),
                event: None,
                message: format!("{} records all write slot {s}", ws.len()),
                witness: None,
            });
        }
    }
    for (e, rs) in recorders_of.iter().enumerate() {
        if rs.len() > 1 {
            diags.push(Diagnostic {
                kind: DiagKind::DuplicateRecorder,
                ops: rs.clone(),
                slot: None,
                event: Some(e as u32),
                message: format!(
                    "{} records all record event {e}; waiters are released at the first, \
                     so ordering against the later recorders is illusory",
                    rs.len()
                ),
                witness: None,
            });
        }
    }
    for (i, op) in tape.ops().iter().enumerate() {
        for &e in tape.waits(op) {
            if recorders_of[e as usize].is_empty() {
                diags.push(Diagnostic {
                    kind: DiagKind::OrphanWait,
                    ops: vec![i as u32],
                    slot: None,
                    event: Some(e),
                    message: format!(
                        "record #{i} (stream {}) waits on event {e}, which nothing records: \
                         the stream blocks forever at replay time",
                        op.stream
                    ),
                    witness: None,
                });
            }
        }
    }

    // ---- Pass 2: happens-before closure and deadlock cycles.
    let hb = hb::closure(tape);
    report.hb_edges = hb.n_edges;
    if let Some(cycle) = &hb.cycle {
        let chain = cycle.iter().map(|o| format!("#{o}")).collect::<Vec<_>>().join(" → ");
        let first = cycle.first().copied().unwrap_or(0);
        report.diagnostics.push(Diagnostic {
            kind: DiagKind::HbCycle,
            ops: cycle.clone(),
            slot: None,
            event: None,
            message: format!(
                "cyclic wait/record chain {chain} → #{first}: every record on it \
                 transitively waits on itself, so none can start"
            ),
            witness: None,
        });
        // Ordering is undefined on a cyclic relation; the remaining
        // passes would report noise derived from the same root cause.
        return report;
    }

    // ---- Pass 3: slot-level race / use-before-def.
    let access = slot_access(tape);
    let diags = &mut report.diagnostics;
    for s in 0..n_slots {
        let Some(&w) = access.writer[s].as_ref() else {
            for &r in &access.readers[s] {
                diags.push(Diagnostic {
                    kind: DiagKind::UseBeforeDef,
                    ops: vec![r],
                    slot: Some(s as u32),
                    event: None,
                    message: format!("record #{r} reads slot {s}, which nothing writes"),
                    witness: None,
                });
            }
            continue;
        };
        for &r in &access.readers[s] {
            if r == w {
                continue; // self-dependency, already reported in pass 1
            }
            let (wu, ru) = (w as usize, r as usize);
            if hb.happens_before(wu, ru) {
                continue;
            }
            if hb.happens_before(ru, wu) {
                diags.push(Diagnostic {
                    kind: DiagKind::UseBeforeDef,
                    ops: vec![r, w],
                    slot: Some(s as u32),
                    event: None,
                    message: format!(
                        "record #{r} reads slot {s} but is ordered before its writer #{w}"
                    ),
                    witness: None,
                });
            } else {
                let wop = tape.op(wu);
                let rop = tape.op(ru);
                diags.push(Diagnostic {
                    kind: DiagKind::Race,
                    ops: vec![w, r],
                    slot: Some(s as u32),
                    event: None,
                    message: format!(
                        "write of slot {s} by #{w} (node {}, stream {}) races its read by \
                         #{r} (node {}, stream {}): no happens-before path orders them",
                        wop.node, wop.stream, rop.node, rop.stream
                    ),
                    witness: Some(Witness { prefix: hb.joint_prefix(wu, ru), focus: (w, r) }),
                });
            }
        }
    }

    // ---- Pass 4: output reachability from the inputs (skipped for
    // input-free tapes, e.g. payload-free DAG tapes in property tests).
    if !tape.input_slots().is_empty() {
        let mut reached = vec![false; n_slots];
        for &(s, _) in tape.input_slots() {
            reached[s] = true;
        }
        // Submission order is topological for legal tapes, but a
        // mutated one may not be — iterate to a fixpoint.
        loop {
            let mut changed = false;
            for op in tape.ops() {
                if reached[op.out_slot as usize] {
                    continue;
                }
                let feeds = tape.args(op).iter().any(|a| match a {
                    TapeArg::Slot(s) => reached[*s as usize],
                    TapeArg::Weight(_) => false,
                });
                if feeds {
                    reached[op.out_slot as usize] = true;
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }
        if !reached[tape.output_slot()] {
            report.diagnostics.push(Diagnostic {
                kind: DiagKind::DeadOutput,
                ops: Vec::new(),
                slot: Some(tape.output_slot() as u32),
                event: None,
                message: format!(
                    "output slot {} is not reachable from any input slot through \
                     argument edges",
                    tape.output_slot()
                ),
                witness: None,
            });
        }
    }

    // ---- Pass 5: arena-aliasing soundness.
    if let Some(plan) = arena {
        verify_arena(tape, plan, &access, &hb, &mut report);
    }

    report
}

/// Check the arena plan: extents inside the reservation, and every
/// byte-overlapping slot pair ordered so one slot's lifetime fully
/// precedes the other's definition ("retires below" — derived here
/// independently of `aot::memory::lifetime`, which this audits).
fn verify_arena(
    tape: &ReplayTape,
    plan: &ArenaPlan,
    access: &SlotAccess,
    hb: &hb::HbClosure,
    report: &mut VerifyReport,
) {
    let n_slots = tape.n_slots();
    let diags = &mut report.diagnostics;
    if plan.offsets.len() != n_slots || plan.rounded_sizes.len() != n_slots {
        diags.push(Diagnostic {
            kind: DiagKind::ArenaBounds,
            ops: Vec::new(),
            slot: None,
            event: None,
            message: format!(
                "arena plan covers {} offsets / {} sizes but the tape has {n_slots} slots",
                plan.offsets.len(),
                plan.rounded_sizes.len()
            ),
            witness: None,
        });
        return;
    }
    // Written extent of each slot: the bytes replay actually touches.
    let bytes: Vec<u64> = tape.slot_bytes();
    let mut bounded = true;
    for s in 0..n_slots {
        if bytes[s] == 0 {
            continue;
        }
        if plan.rounded_sizes[s] < bytes[s] {
            bounded = false;
            diags.push(Diagnostic {
                kind: DiagKind::ArenaBounds,
                ops: Vec::new(),
                slot: Some(s as u32),
                event: None,
                message: format!(
                    "slot {s} reserves {} bytes but replay writes {}",
                    plan.rounded_sizes[s], bytes[s]
                ),
                witness: None,
            });
        }
        if plan.offsets[s] + bytes[s] > plan.arena_bytes {
            bounded = false;
            diags.push(Diagnostic {
                kind: DiagKind::ArenaBounds,
                ops: Vec::new(),
                slot: Some(s as u32),
                event: None,
                message: format!(
                    "slot {s} extent [{}, {}) runs past the {}-byte reservation",
                    plan.offsets[s],
                    plan.offsets[s] + bytes[s],
                    plan.arena_bytes
                ),
                witness: None,
            });
        }
    }
    if !bounded {
        return;
    }

    let is_input = {
        let mut v = vec![false; n_slots];
        for &(s, _) in tape.input_slots() {
            v[s] = true;
        }
        v
    };
    let output = tape.output_slot();

    // All accesses (writer + readers) of a slot, by tape index.
    let accesses = |s: usize| -> Vec<u32> {
        let mut v: Vec<u32> = access.writer[s].iter().copied().collect();
        v.extend_from_slice(&access.readers[s]);
        v
    };
    // `a` retires below `b`: every access of `a` strictly
    // happens-before `b`'s definition, `a` is not the output (it must
    // survive to the end of replay), and `b` is not an input (its bytes
    // are host-filled before replay starts, so nothing precedes them).
    let retires_below = |a: usize, b: usize| -> bool {
        if a == output || is_input[b] {
            return false;
        }
        let Some(db) = access.writer[b] else {
            return true; // b is never written: no footprint to collide with
        };
        accesses(a).iter().all(|&x| hb.happens_before(x as usize, db as usize))
    };

    for i in 0..n_slots {
        if bytes[i] == 0 {
            continue;
        }
        let (oi, ei) = (plan.offsets[i], plan.offsets[i] + bytes[i]);
        for j in i + 1..n_slots {
            if bytes[j] == 0 {
                continue;
            }
            let (oj, ej) = (plan.offsets[j], plan.offsets[j] + bytes[j]);
            if ei <= oj || ej <= oi {
                continue; // written extents disjoint
            }
            report.alias_pairs_checked += 1;
            if retires_below(i, j) || retires_below(j, i) {
                continue;
            }
            let lo = oi.max(oj);
            let hi = ei.min(ej);
            let (wit, detail) = alias_witness(i, j, access, hb, &accesses);
            report.diagnostics.push(Diagnostic {
                kind: DiagKind::AliasOverlap,
                ops: wit
                    .as_ref()
                    .map(|w| vec![w.focus.0, w.focus.1])
                    .unwrap_or_default(),
                slot: Some(i as u32),
                event: None,
                message: format!(
                    "slots {i} and {j} share arena bytes [{lo}, {hi}) but neither retires \
                     below the other{detail}"
                ),
                witness: wit,
            });
        }
    }
}

/// Concrete evidence for an alias overlap: prefer an *unordered* access
/// pair (a true race on the shared bytes); fall back to an ordered
/// corruption sequence (an access of one slot after the other's
/// definition overwrote the bytes).
fn alias_witness(
    i: usize,
    j: usize,
    access: &SlotAccess,
    hb: &hb::HbClosure,
    accesses: &dyn Fn(usize) -> Vec<u32>,
) -> (Option<Witness>, String) {
    let ai = accesses(i);
    let aj = accesses(j);
    for &x in &ai {
        for &y in &aj {
            if x != y && !hb.ordered(x as usize, y as usize) {
                return (
                    Some(Witness { prefix: hb.joint_prefix(x as usize, y as usize), focus: (x, y) }),
                    format!(": #{x} (slot {i}) and #{y} (slot {j}) are unordered"),
                );
            }
        }
    }
    // All cross accesses ordered, yet neither retires: some access of
    // the earlier-defined slot lands after the later definition.
    for (a, b, aa) in [(i, j, &ai), (j, i, &aj)] {
        if let Some(db) = access.writer[b] {
            if let Some(&x) =
                aa.iter().find(|&&x| x != db && hb.happens_before(db as usize, x as usize))
            {
                return (
                    Some(Witness { prefix: hb.joint_prefix(db as usize, x as usize), focus: (db, x) }),
                    format!(
                        ": #{x} touches slot {a} after #{db} redefined the shared bytes \
                         for slot {b}"
                    ),
                );
            }
        }
    }
    (None, String::new())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aot::memory::{happens_before_conflicts, plan_with_conflicts, ArenaPlan};
    use crate::aot::tape::NodeMeta;
    use crate::matching::MatchingAlgo;
    use crate::models;
    use crate::stream::rewrite::{rewrite, rewrite_single_stream, NodePlan};
    use crate::stream::LaunchPlan;

    /// Hand-build a tape from explicit per-record plans.
    /// Each entry: (node, stream, waits, records, args, out_len, role).
    #[allow(clippy::type_complexity)]
    fn build_tape(
        n_slots: usize,
        n_streams: usize,
        n_events: usize,
        recs: &[(usize, usize, Vec<usize>, Vec<usize>, Vec<u32>, usize, TapeRole)],
        output: usize,
    ) -> ReplayTape {
        let order = recs
            .iter()
            .map(|(node, stream, waits, records, _, _, _)| NodePlan {
                node: *node,
                stream: *stream,
                wait_events: waits.clone(),
                record_events: records.clone(),
            })
            .collect();
        let mut stream_of = vec![0usize; n_slots];
        for (node, stream, ..) in recs {
            stream_of[*node] = *stream;
        }
        let plan = LaunchPlan { order, n_streams, n_events, stream_of };
        ReplayTape::compile(&plan, output, |v| {
            let r = recs.iter().find(|(node, ..)| *node == v).expect("record for node");
            NodeMeta {
                role: r.6,
                out_len: r.5,
                args: r.4.iter().map(|&s| TapeArg::Slot(s)).collect(),
            }
        })
    }

    #[test]
    fn model_zoo_tapes_verify_clean_with_their_arenas() {
        for name in ["mini_inception", "resnet50_cifar", "inception_v3"] {
            let g = models::build(name, 2);
            for plan in [rewrite(&g, MatchingAlgo::HopcroftKarp), rewrite_single_stream(&g)] {
                let tape = ReplayTape::for_op_graph(&g, &plan, 4096);
                let bytes = tape.slot_bytes();
                let arena = plan_with_conflicts(&bytes, &happens_before_conflicts(&tape));
                let report = verify_with_arena(&tape, &arena);
                assert!(report.is_clean(), "{name}: {}", report.render());
                assert!(report.hb_edges > 0);
                let unshared = verify_with_arena(&tape, &ArenaPlan::unshared(&bytes));
                assert!(unshared.is_clean(), "{name} unshared: {}", unshared.render());
                assert_eq!(unshared.alias_pairs_checked, 0, "unshared slots never overlap");
            }
        }
    }

    /// Two streams, one dependency, no sync: a race with a witness.
    #[test]
    fn unsynchronized_cross_stream_read_is_a_race_with_witness() {
        let t = build_tape(
            2,
            2,
            0,
            &[
                (0, 0, vec![], vec![], vec![], 8, TapeRole::Task),
                (1, 1, vec![], vec![], vec![0], 8, TapeRole::Task),
            ],
            1,
        );
        let r = verify(&t);
        assert!(r.has(DiagKind::Race), "{}", r.render());
        let d = r.diagnostics.iter().find(|d| d.kind == DiagKind::Race).expect("race");
        assert_eq!(d.slot, Some(0));
        let w = d.witness.as_ref().expect("race carries a witness");
        assert_eq!(w.focus, (0, 1));
        assert!(w.prefix.is_empty(), "no predecessors: both eligible at start");
        // The same plan with a record→wait edge is clean.
        let t = build_tape(
            2,
            2,
            1,
            &[
                (0, 0, vec![], vec![0], vec![], 8, TapeRole::Task),
                (1, 1, vec![0], vec![], vec![0], 8, TapeRole::Task),
            ],
            1,
        );
        assert!(verify(&t).is_clean());
    }

    #[test]
    fn orphan_wait_is_reported() {
        let t = build_tape(
            2,
            1,
            2,
            &[
                (0, 0, vec![], vec![0], vec![], 8, TapeRole::Task),
                (1, 0, vec![1], vec![], vec![0], 8, TapeRole::Task),
            ],
            1,
        );
        let r = verify(&t);
        let d = r.diagnostics.iter().find(|d| d.kind == DiagKind::OrphanWait).expect("orphan");
        assert_eq!(d.event, Some(1));
        assert_eq!(d.ops, vec![1]);
    }

    /// Cross-stream mutual waits: #1 waits on an event recorded by #2
    /// (reachable only after #1's stream-mate #0... arranged so the
    /// record→wait edges close a cycle through program order).
    #[test]
    fn cyclic_wait_record_chain_is_a_deadlock() {
        // stream 0: #0 waits e1 then records e0; stream 1: #1 waits e0,
        // records e1. #0 → needs e1 ← #1 → needs e0 ← #0: cycle.
        let t = build_tape(
            2,
            2,
            2,
            &[
                (0, 0, vec![1], vec![0], vec![], 8, TapeRole::Task),
                (1, 1, vec![0], vec![1], vec![], 8, TapeRole::Task),
            ],
            1,
        );
        let r = verify(&t);
        let d = r.diagnostics.iter().find(|d| d.kind == DiagKind::HbCycle).expect("cycle");
        assert_eq!(d.ops.len(), 2, "two-record cycle: {}", d.message);
    }

    #[test]
    fn self_wait_is_a_one_record_cycle() {
        let t = build_tape(
            1,
            1,
            1,
            &[(0, 0, vec![0], vec![0], vec![], 8, TapeRole::Task)],
            0,
        );
        let r = verify(&t);
        let d = r.diagnostics.iter().find(|d| d.kind == DiagKind::HbCycle).expect("cycle");
        assert_eq!(d.ops, vec![0]);
    }

    #[test]
    fn overlapping_live_slots_are_an_alias_overlap() {
        // 0 → 1 → 2 on one stream; slots 0 and 2 share bytes. Slot 0 is
        // read by #1 which happens-before #2's def, so 0 retires below 2
        // → clean. Overlap 1 with 0 instead: #1 defines slot 1 *before*
        // #2 reads... build the dirty case: overlap slots 1 and 2; slot
        // 1 is read by #2 itself, so 1 cannot retire below 2 and 2 is
        // defined after 1: overlap must be flagged.
        let t = build_tape(
            3,
            1,
            0,
            &[
                (0, 0, vec![], vec![], vec![], 8, TapeRole::Task),
                (1, 0, vec![], vec![], vec![0], 8, TapeRole::Task),
                (2, 0, vec![], vec![], vec![1], 8, TapeRole::Task),
            ],
            2,
        );
        let bytes = t.slot_bytes();
        // Legal: slots 0 and 2 share an offset (0 retires below 2).
        let clean = ArenaPlan {
            offsets: vec![0, 512, 0],
            rounded_sizes: vec![512, 512, 512],
            arena_bytes: 1024,
        };
        assert_eq!(bytes.iter().filter(|&&b| b > 0).count(), 3);
        let r = verify_with_arena(&t, &clean);
        assert!(r.is_clean(), "{}", r.render());
        assert_eq!(r.alias_pairs_checked, 1);
        // Illegal: producer slot 1 shares bytes with its consumer's
        // output slot 2.
        let dirty = ArenaPlan {
            offsets: vec![0, 512, 512],
            rounded_sizes: vec![512, 512, 512],
            arena_bytes: 1024,
        };
        let r = verify_with_arena(&t, &dirty);
        let d =
            r.diagnostics.iter().find(|d| d.kind == DiagKind::AliasOverlap).expect("overlap");
        assert!(d.witness.is_some(), "alias overlap carries a witness: {}", d.message);
    }

    #[test]
    fn extent_past_reservation_is_arena_bounds() {
        let t = build_tape(
            1,
            1,
            0,
            &[(0, 0, vec![], vec![], vec![], 8, TapeRole::Task)],
            0,
        );
        let plan =
            ArenaPlan { offsets: vec![512], rounded_sizes: vec![512], arena_bytes: 512 };
        let r = verify_with_arena(&t, &plan);
        assert!(r.has(DiagKind::ArenaBounds), "{}", r.render());
    }

    #[test]
    fn out_of_range_event_is_bad_index_and_short_circuits() {
        let t = build_tape(
            2,
            1,
            1,
            &[
                (0, 0, vec![], vec![7], vec![], 8, TapeRole::Task),
                (1, 0, vec![], vec![], vec![0], 8, TapeRole::Task),
            ],
            1,
        );
        let r = verify(&t);
        assert!(r.has(DiagKind::BadIndex), "{}", r.render());
        assert_eq!(r.diagnostics.len(), 1, "bad indices short-circuit later passes");
    }

    #[test]
    fn self_dependency_is_bad_index() {
        let t = build_tape(
            1,
            1,
            0,
            &[(0, 0, vec![], vec![], vec![0], 8, TapeRole::Task)],
            0,
        );
        assert!(verify(&t).has(DiagKind::BadIndex));
    }

    #[test]
    fn use_before_def_when_reader_precedes_writer() {
        // Same stream, reader submitted before the writer.
        let t = build_tape(
            2,
            1,
            0,
            &[
                (1, 0, vec![], vec![], vec![0], 8, TapeRole::Task),
                (0, 0, vec![], vec![], vec![], 8, TapeRole::Task),
            ],
            1,
        );
        let r = verify(&t);
        assert!(r.has(DiagKind::UseBeforeDef), "{}", r.render());
    }

    #[test]
    fn duplicate_recorder_is_reported() {
        let t = build_tape(
            2,
            1,
            1,
            &[
                (0, 0, vec![], vec![0], vec![], 8, TapeRole::Task),
                (1, 0, vec![], vec![0], vec![0], 8, TapeRole::Task),
            ],
            1,
        );
        let r = verify(&t);
        let d = r
            .diagnostics
            .iter()
            .find(|d| d.kind == DiagKind::DuplicateRecorder)
            .expect("duplicate recorder");
        assert_eq!(d.ops, vec![0, 1]);
    }

    #[test]
    fn report_json_round_trips_through_the_parser() {
        let g = models::build("mini_inception", 1);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        let tape = ReplayTape::for_op_graph(&g, &plan, 4096);
        let report = verify(&tape);
        let parsed = crate::util::json::parse_json(&report.to_json()).expect("valid json");
        assert_eq!(parsed.get("clean"), Some(&crate::util::json::JsonValue::Bool(true)));
        assert_eq!(
            parsed.get("n_ops").and_then(|v| v.as_u64()),
            Some(tape.n_ops() as u64)
        );
        // And a dirty report keeps the diagnostics array well-formed.
        let t = build_tape(
            2,
            2,
            0,
            &[
                (0, 0, vec![], vec![], vec![], 8, TapeRole::Task),
                (1, 1, vec![], vec![], vec![0], 8, TapeRole::Task),
            ],
            1,
        );
        let dirty = verify(&t);
        let parsed = crate::util::json::parse_json(&dirty.to_json()).expect("valid json");
        let diags = parsed.get("diagnostics").and_then(|v| v.as_array()).expect("array");
        assert_eq!(diags.len(), dirty.diagnostics.len());
        assert_eq!(diags[0].get("kind").and_then(|v| v.as_str()), Some("race"));
    }

    #[test]
    fn default_mode_tracks_build_profile() {
        let expect =
            if cfg!(debug_assertions) { VerifyMode::Warn } else { VerifyMode::Off };
        assert_eq!(VerifyMode::default(), expect);
    }
}
