//! Seeded, deterministic fault injection for chaos-hardening the
//! serving stack.
//!
//! A [`FaultPlan`] is a probability grammar over the failure modes a
//! production runtime built on AoT schedules must survive even though
//! the schedule cannot predict them:
//!
//! * **op error / op delay** — a tape op panics or stalls mid-replay
//!   (injected inside the executor's per-op dispatch, so the parallel
//!   worker pools exercise their real panic-recovery paths);
//! * **replay-join timeout** — the replay wedges and the context is
//!   poisoned, exactly like a real timed-out join
//!   ([`ReplayContext::replay`](crate::engine::executor::ReplayContext::replay));
//! * **worker death / arena exhaustion** — a whole replay fails outright
//!   with a transient error;
//! * **engine error / engine panic** — an `infer_batch` call fails
//!   before reaching the replay context (the [`ChaosEngine`] wrapper,
//!   wired by `Runtime::builder().fault_plan(..)` through the existing
//!   engine-factory hook).
//!
//! Every decision is a **pure hash** of `(seed, fault kind, replay
//! index, op/call index)` — no shared RNG state — so concurrent lanes,
//! bounded retries, and re-runs of the same seed draw identical fault
//! sequences, and the DES mirror
//! ([`sim::simulate_faults`](crate::sim::simulate_faults)) can predict
//! measured completed/retried/failed counts exactly.
//!
//! The recovery side lives in the lane scheduler
//! ([`serving::lanes`](crate::serving::lanes)): transient failures are
//! retried in place under a bounded, deadline-aware [`RetryPolicy`];
//! a poisoned context kills its lane, the dispatcher's supervision
//! pass replaces the lane and re-admits its in-flight jobs.

use crate::coordinator::InferEngine;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Marker every injected failure message carries, so operators (and
/// tests) can tell chaos from organic failures.
pub const INJECTED: &str = "injected fault";

// Per-kind hash salts: distinct fault kinds draw independent streams
// from one seed.
const SALT_OP_ERROR: u64 = 0x0FA1_1ED0;
const SALT_OP_DELAY: u64 = 0x0DE1_A7ED;
const SALT_ENGINE_ERROR: u64 = 0x0E66_E44E;
const SALT_ENGINE_PANIC: u64 = 0x0E66_AA1C;
const SALT_WORKER_DEATH: u64 = 0x0D0A_DEAD;
const SALT_JOIN_TIMEOUT: u64 = 0x0707_1AEA;
const SALT_ARENA_EXHAUSTED: u64 = 0x0A4E_AA00;

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A fault injected around one tape op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpFault {
    /// The op's execution panics (`"injected fault: op .."`).
    Error,
    /// The op stalls for [`FaultPlan::delay`] before executing.
    Delay,
}

/// A fault injected at replay entry, before any op runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplayFault {
    /// The replay "wedges": the context poisons itself and returns the
    /// same error a real timed-out join produces. Fatal to a lane.
    JoinTimeout,
    /// A replay worker dies mid-lease; the replay fails, transiently.
    WorkerDeath,
    /// The arena cannot satisfy the replay's reservation; transient.
    ArenaExhausted,
}

/// A fault injected around one whole `infer_batch` call
/// ([`ChaosEngine`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineFault {
    /// The call returns `Err` without executing.
    Error,
    /// The call panics without executing (exercises the lane thread's
    /// catch-unwind path).
    Panic,
}

/// Seeded probability grammar over the injectable failure modes. All
/// probabilities default to 0 (a no-op plan); [`Default`] is the
/// fault-free plan with seed 0.
///
/// Decisions are stateless hashes, so a plan can be cloned freely:
/// every copy (live engine wrapper, executor injector, DES mirror)
/// draws the identical fault sequence for the same indices. Use
/// [`derive`](Self::derive) to fork an independent stream per bucket
/// or per subsystem.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Base seed; every decision hashes it with the fault kind and the
    /// replay/op/call indices.
    pub seed: u64,
    /// Probability an op execution panics mid-replay.
    pub op_error: f64,
    /// Probability an op stalls for [`delay`](Self::delay) first.
    pub op_delay: f64,
    /// Stall length for [`op_delay`](Self::op_delay) spikes.
    pub delay: Duration,
    /// Probability an `infer_batch` call fails with `Err` outright.
    pub engine_error: f64,
    /// Probability an `infer_batch` call panics outright.
    pub engine_panic: f64,
    /// Probability a replay fails with a worker-death error.
    pub worker_death: f64,
    /// Probability a replay "wedges" and poisons its context — fatal
    /// to the owning lane until supervision replaces it.
    pub join_timeout: f64,
    /// Probability a replay fails with an arena-exhaustion error.
    pub arena_exhaustion: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            op_error: 0.0,
            op_delay: 0.0,
            delay: Duration::ZERO,
            engine_error: 0.0,
            engine_panic: 0.0,
            worker_death: 0.0,
            join_timeout: 0.0,
            arena_exhaustion: 0.0,
        }
    }
}

impl FaultPlan {
    /// A fault-free plan with the given seed (set probabilities on it).
    pub fn seeded(seed: u64) -> FaultPlan {
        FaultPlan { seed, ..FaultPlan::default() }
    }

    /// `true` when no fault can ever fire (all probabilities zero).
    pub fn is_noop(&self) -> bool {
        self.op_error == 0.0
            && self.op_delay == 0.0
            && self.engine_error == 0.0
            && self.engine_panic == 0.0
            && self.worker_death == 0.0
            && self.join_timeout == 0.0
            && self.arena_exhaustion == 0.0
    }

    /// `true` when any replay-level fault (op error/delay, worker
    /// death, join timeout, arena exhaustion) can fire — the executor
    /// only installs an injector when this holds.
    pub fn has_replay_faults(&self) -> bool {
        self.op_error > 0.0
            || self.op_delay > 0.0
            || self.worker_death > 0.0
            || self.join_timeout > 0.0
            || self.arena_exhaustion > 0.0
    }

    /// Fork an independent decision stream (same probabilities, hashed
    /// seed). The runtime derives one stream per bucket: the engine
    /// wrapper for bucket `b` runs `plan.derive(b as u64)`, and the
    /// executor-level injector runs
    /// `plan.derive(b as u64 ^ FaultPlan::REPLAY_SALT)` — the DES
    /// mirror must apply the same derivation to predict a bucket.
    pub fn derive(&self, salt: u64) -> FaultPlan {
        FaultPlan { seed: splitmix64(self.seed ^ salt), ..self.clone() }
    }

    /// Derivation salt separating a bucket's executor-level injector
    /// stream from its engine-wrapper stream (see [`derive`](Self::derive)).
    pub const REPLAY_SALT: u64 = 0x4EA1_5A17;

    /// Derivation salt separating device replicas of a cluster (see
    /// [`derive_replica`](Self::derive_replica)).
    pub const REPLICA_SALT: u64 = 0x0C1A_57E4;

    /// Fork the per-replica decision stream for device replica
    /// `replica` of a cluster: each replica draws independent faults
    /// from one base plan, and a replica rebuilt at the same index
    /// replays the identical schedule. The per-bucket derivations
    /// ([`derive`](Self::derive)) are applied on top by the replica's
    /// own runtime, so streams never collide across
    /// (replica, bucket, layer).
    pub fn derive_replica(&self, replica: usize) -> FaultPlan {
        self.derive(Self::REPLICA_SALT ^ ((replica as u64) << 17))
    }

    /// Uniform roll in `[0, 1)` for `(kind, a, b)`.
    fn roll(&self, salt: u64, a: u64, b: u64) -> f64 {
        let mut h = splitmix64(self.seed ^ salt);
        h = splitmix64(h ^ a);
        h = splitmix64(h ^ b);
        (h >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Fault decision for op `op` of replay number `replay`.
    pub fn op_fault(&self, replay: u64, op: u64) -> Option<OpFault> {
        if self.op_error > 0.0 && self.roll(SALT_OP_ERROR, replay, op) < self.op_error {
            return Some(OpFault::Error);
        }
        if self.op_delay > 0.0 && self.roll(SALT_OP_DELAY, replay, op) < self.op_delay {
            return Some(OpFault::Delay);
        }
        None
    }

    /// Fault decision for replay number `replay` (checked at entry).
    pub fn replay_fault(&self, replay: u64) -> Option<ReplayFault> {
        if self.join_timeout > 0.0 && self.roll(SALT_JOIN_TIMEOUT, replay, 0) < self.join_timeout
        {
            return Some(ReplayFault::JoinTimeout);
        }
        if self.worker_death > 0.0 && self.roll(SALT_WORKER_DEATH, replay, 0) < self.worker_death
        {
            return Some(ReplayFault::WorkerDeath);
        }
        if self.arena_exhaustion > 0.0
            && self.roll(SALT_ARENA_EXHAUSTED, replay, 0) < self.arena_exhaustion
        {
            return Some(ReplayFault::ArenaExhausted);
        }
        None
    }

    /// Fault decision for `infer_batch` call number `call` of one
    /// engine instance — the grammar [`ChaosEngine`] and
    /// [`sim::simulate_faults`](crate::sim::simulate_faults) share.
    pub fn engine_fault(&self, call: u64) -> Option<EngineFault> {
        if self.engine_error > 0.0 && self.roll(SALT_ENGINE_ERROR, call, 0) < self.engine_error {
            return Some(EngineFault::Error);
        }
        if self.engine_panic > 0.0 && self.roll(SALT_ENGINE_PANIC, call, 0) < self.engine_panic {
            return Some(EngineFault::Panic);
        }
        None
    }
}

/// A [`FaultPlan`] plus the per-context replay counter the executor
/// consults. Shared with replay workers (`&self` decisions only);
/// replays themselves are serialized by `&mut ReplayContext`, so the
/// current replay index is stable while its ops run.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    /// Replays begun on this context (next replay's index).
    replays: AtomicU64,
    /// Index of the replay currently executing.
    current: AtomicU64,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> FaultInjector {
        FaultInjector { plan, replays: AtomicU64::new(0), current: AtomicU64::new(0) }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Advance to the next replay: returns its index and any
    /// replay-level fault to apply before running ops.
    pub fn begin_replay(&self) -> (u64, Option<ReplayFault>) {
        let idx = self.replays.fetch_add(1, Ordering::SeqCst);
        self.current.store(idx, Ordering::SeqCst);
        (idx, self.plan.replay_fault(idx))
    }

    /// Fault decision for op `op` of the replay currently executing.
    pub fn op_fault(&self, op: u64) -> Option<OpFault> {
        self.plan.op_fault(self.current.load(Ordering::SeqCst), op)
    }

    /// Stall length for injected [`OpFault::Delay`] spikes.
    pub fn delay(&self) -> Duration {
        self.plan.delay
    }
}

/// Bounded, deadline-aware retry budget for failed lane jobs.
///
/// A job may execute at most `max_retries + 1` times; each
/// re-execution waits `backoff` first and is skipped entirely (the
/// job resolves `Failed`) if every live request in it would already be
/// past its deadline when the backoff elapses — a retry never runs
/// past a request's deadline.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Re-executions allowed after the first attempt fails.
    pub max_retries: u32,
    /// Wait before each re-execution.
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 2, backoff: Duration::ZERO }
    }
}

/// Fault-injecting [`InferEngine`] wrapper: consults
/// [`FaultPlan::engine_fault`] with a per-instance call counter before
/// delegating. `Runtime::builder().fault_plan(..)` wraps every lane
/// engine in one (stream derived per bucket), but it composes with any
/// engine via `build_with_factory`.
pub struct ChaosEngine<E> {
    inner: E,
    plan: FaultPlan,
    calls: u64,
}

impl<E> ChaosEngine<E> {
    pub fn new(inner: E, plan: FaultPlan) -> ChaosEngine<E> {
        ChaosEngine { inner, plan, calls: 0 }
    }

    /// The wrapped engine.
    pub fn inner(&self) -> &E {
        &self.inner
    }

    /// `infer_batch` calls attempted so far (fault decisions consumed).
    pub fn calls(&self) -> u64 {
        self.calls
    }
}

impl<E: InferEngine> InferEngine for ChaosEngine<E> {
    fn batch_sizes(&self) -> Vec<usize> {
        self.inner.batch_sizes()
    }

    fn example_len(&self) -> usize {
        self.inner.example_len()
    }

    fn output_len(&self) -> usize {
        self.inner.output_len()
    }

    fn infer_batch(&mut self, bucket: usize, input: &[f32]) -> Result<Vec<f32>> {
        let call = self.calls;
        self.calls += 1;
        match self.plan.engine_fault(call) {
            Some(EngineFault::Error) => {
                anyhow::bail!("{INJECTED}: engine call {call} failed")
            }
            Some(EngineFault::Panic) => panic!("{INJECTED}: engine call {call} panicked"),
            None => {}
        }
        self.inner.infer_batch(bucket, input)
    }

    fn stream_count(&self, bucket: usize) -> Option<usize> {
        self.inner.stream_count(bucket)
    }

    fn reserved_bytes(&self, bucket: usize) -> Option<u64> {
        self.inner.reserved_bytes(bucket)
    }

    fn steals(&self) -> Option<u64> {
        self.inner.steals()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chaotic_plan(seed: u64) -> FaultPlan {
        FaultPlan {
            op_error: 0.3,
            op_delay: 0.2,
            engine_error: 0.25,
            engine_panic: 0.1,
            worker_death: 0.15,
            join_timeout: 0.1,
            arena_exhaustion: 0.1,
            ..FaultPlan::seeded(seed)
        }
    }

    #[test]
    fn decisions_are_deterministic_and_stateless() {
        let plan = chaotic_plan(42);
        let clone = plan.clone();
        for replay in 0..50u64 {
            assert_eq!(plan.replay_fault(replay), clone.replay_fault(replay));
            for op in 0..20u64 {
                assert_eq!(plan.op_fault(replay, op), clone.op_fault(replay, op));
            }
            assert_eq!(plan.engine_fault(replay), clone.engine_fault(replay));
        }
        // Re-querying an index never perturbs later decisions.
        let first: Vec<_> = (0..50).map(|c| plan.engine_fault(c)).collect();
        let _ = plan.engine_fault(7);
        let again: Vec<_> = (0..50).map(|c| plan.engine_fault(c)).collect();
        assert_eq!(first, again);
    }

    #[test]
    fn probabilities_gate_the_fault_kinds() {
        let noop = FaultPlan::seeded(9);
        assert!(noop.is_noop());
        assert!(!noop.has_replay_faults());
        for i in 0..200u64 {
            assert_eq!(noop.op_fault(i, i), None);
            assert_eq!(noop.replay_fault(i), None);
            assert_eq!(noop.engine_fault(i), None);
        }
        let certain = FaultPlan { engine_error: 1.0, ..FaultPlan::seeded(9) };
        assert!(!certain.is_noop());
        assert!(!certain.has_replay_faults(), "engine faults are not replay faults");
        for i in 0..50u64 {
            assert_eq!(certain.engine_fault(i), Some(EngineFault::Error));
        }
        let wedge = FaultPlan { join_timeout: 1.0, ..FaultPlan::seeded(9) };
        assert!(wedge.has_replay_faults());
        assert_eq!(wedge.replay_fault(3), Some(ReplayFault::JoinTimeout));
    }

    #[test]
    fn rates_roughly_match_probabilities() {
        let plan = FaultPlan { engine_error: 0.25, ..FaultPlan::seeded(1234) };
        let n = 4000u64;
        let hits = (0..n).filter(|&c| plan.engine_fault(c).is_some()).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.03, "observed rate {rate} for p=0.25");
    }

    #[test]
    fn derived_streams_are_independent_but_reproducible() {
        let plan = chaotic_plan(7);
        let a = plan.derive(1);
        let b = plan.derive(2);
        assert_eq!(a.seed, plan.derive(1).seed, "derivation is deterministic");
        assert_ne!(a.seed, b.seed);
        assert_ne!(a.seed, plan.seed);
        // Streams diverge somewhere early.
        let differs = (0..64u64).any(|c| a.engine_fault(c) != b.engine_fault(c));
        assert!(differs, "derived streams should not be identical");
    }

    #[test]
    fn injector_tracks_replays_and_scopes_op_faults_to_the_current_replay() {
        let plan = chaotic_plan(77);
        let inj = FaultInjector::new(plan.clone());
        for expect in 0..20u64 {
            let (idx, fault) = inj.begin_replay();
            assert_eq!(idx, expect);
            assert_eq!(fault, plan.replay_fault(expect));
            for op in 0..8u64 {
                assert_eq!(inj.op_fault(op), plan.op_fault(expect, op));
            }
        }
    }

    #[test]
    fn retry_policy_default_is_bounded() {
        let p = RetryPolicy::default();
        assert!(p.max_retries >= 1);
        assert_eq!(p.backoff, Duration::ZERO);
    }

    struct FixedEngine;
    impl InferEngine for FixedEngine {
        fn batch_sizes(&self) -> Vec<usize> {
            vec![1]
        }
        fn example_len(&self) -> usize {
            2
        }
        fn output_len(&self) -> usize {
            2
        }
        fn infer_batch(&mut self, _bucket: usize, input: &[f32]) -> Result<Vec<f32>> {
            Ok(input.to_vec())
        }
    }

    #[test]
    fn chaos_engine_injects_errors_and_passes_clean_calls_through() {
        let plan = FaultPlan { engine_error: 0.4, ..FaultPlan::seeded(2024) };
        let mut chaos = ChaosEngine::new(FixedEngine, plan.clone());
        assert_eq!(chaos.batch_sizes(), vec![1]);
        let mut failures = Vec::new();
        for call in 0..40u64 {
            let out = chaos.infer_batch(1, &[1.0, 2.0]);
            match plan.engine_fault(call) {
                Some(EngineFault::Error) => {
                    let msg = format!("{:#}", out.unwrap_err());
                    assert!(msg.contains(INJECTED), "marked as injected: {msg}");
                    failures.push(call);
                }
                Some(EngineFault::Panic) => unreachable!("p(panic)=0"),
                None => assert_eq!(out.unwrap(), vec![1.0, 2.0]),
            }
        }
        assert!(!failures.is_empty(), "p=0.4 over 40 calls should fail at least once");
        assert_eq!(chaos.calls(), 40);
    }

    #[test]
    fn chaos_engine_panics_are_marked() {
        let plan = FaultPlan { engine_panic: 1.0, ..FaultPlan::seeded(5) };
        let mut chaos = ChaosEngine::new(FixedEngine, plan);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            chaos.infer_batch(1, &[0.0, 0.0])
        }));
        let payload = caught.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains(INJECTED), "panic payload marked: {msg}");
    }
}
