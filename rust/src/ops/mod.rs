//! Operator library: the DL operators the paper's computation graphs are
//! made of, with tensor shapes, MAC/FLOP counts and memory-traffic
//! estimates, a shape-aware graph builder used by the model zoo, and the
//! TensorRT-style operator-fusion pass the paper implements a subset of.

pub mod builder;
pub mod fusion;
pub mod op;

pub use builder::GraphBuilder;
pub use fusion::fuse_graph;
pub use op::{DType, Op, OpGraph, OpKind, Shape};
