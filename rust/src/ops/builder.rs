//! Shape-aware graph builder: the model zoo's DSL.
//!
//! Each method appends an operator node, infers its output shape from the
//! input nodes (NCHW for images), computes MACs/FLOPs/bytes/params, and
//! wires dependency edges. "Same" padding semantics: `out = ceil(in/stride)`
//! (matches the torchvision shapes the paper's networks use).

use super::op::{DType, Op, OpGraph, OpKind, Shape};
use crate::graph::NodeId;

/// Builder over an [`OpGraph`].
pub struct GraphBuilder {
    g: OpGraph,
    counter: usize,
}

fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

impl GraphBuilder {
    pub fn new() -> Self {
        GraphBuilder { g: OpGraph::new(), counter: 0 }
    }

    fn next_name(&mut self, mnemonic: &str) -> String {
        self.counter += 1;
        format!("{}_{}", mnemonic, self.counter)
    }

    fn shape(&self, id: NodeId) -> &Shape {
        &self.g.node(id).out_shape
    }

    /// Channel dim of an NCHW tensor.
    fn channels(&self, id: NodeId) -> usize {
        self.shape(id).dim(1)
    }

    fn push(&mut self, kind: OpKind, out_shape: Shape, inputs: &[NodeId], cost: Cost) -> NodeId {
        let name = self.next_name(&kind.mnemonic());
        let op = Op {
            name,
            kind,
            out_shape,
            dtype: DType::F32,
            macs: cost.macs,
            flops: cost.flops,
            bytes: cost.bytes,
            params: cost.params,
        };
        let id = self.g.add_node(op);
        for &i in inputs {
            self.g.add_edge(i, id);
        }
        id
    }

    /// Graph input placeholder.
    pub fn input(&mut self, shape: &[usize]) -> NodeId {
        let name = self.next_name("input");
        self.g.add_node(Op::virtual_op(name, OpKind::Input, Shape::new(shape)))
    }

    /// 2D convolution with "same" padding, no bias (BN provides the shift).
    pub fn conv(&mut self, from: NodeId, out_c: usize, k: usize, stride: usize) -> NodeId {
        self.conv_full(from, out_c, (k, k), stride, 1, Pad::Same)
    }

    /// 2D convolution with "valid" padding (Inception-v3 stem/reductions).
    pub fn conv_valid(&mut self, from: NodeId, out_c: usize, k: usize, stride: usize) -> NodeId {
        self.conv_full(from, out_c, (k, k), stride, 1, Pad::Valid)
    }

    /// Rectangular convolution (1×7 / 7×1 factorizations), same padding.
    pub fn conv_rect(&mut self, from: NodeId, out_c: usize, kh: usize, kw: usize) -> NodeId {
        self.conv_full(from, out_c, (kh, kw), 1, 1, Pad::Same)
    }

    /// Depthwise convolution, same padding.
    pub fn dwconv(&mut self, from: NodeId, k: usize, stride: usize) -> NodeId {
        let c = self.channels(from);
        self.conv_full(from, c, (k, k), stride, c, Pad::Same)
    }

    /// Grouped convolution (the general case), same padding.
    pub fn conv_grouped(
        &mut self,
        from: NodeId,
        out_c: usize,
        k: usize,
        stride: usize,
        groups: usize,
    ) -> NodeId {
        self.conv_full(from, out_c, (k, k), stride, groups, Pad::Same)
    }

    fn conv_full(
        &mut self,
        from: NodeId,
        out_c: usize,
        kernel: (usize, usize),
        stride: usize,
        groups: usize,
        pad: Pad,
    ) -> NodeId {
        let (kh, kw) = kernel;
        let in_shape = self.shape(from).clone();
        assert_eq!(in_shape.rank(), 4, "conv expects NCHW");
        let (n, in_c, h, w) = (in_shape.dim(0), in_shape.dim(1), in_shape.dim(2), in_shape.dim(3));
        assert_eq!(in_c % groups, 0, "channels not divisible by groups");
        assert_eq!(out_c % groups, 0, "out channels not divisible by groups");
        let (oh, ow) = match pad {
            Pad::Same => (ceil_div(h, stride), ceil_div(w, stride)),
            Pad::Valid => ((h - kh) / stride + 1, (w - kw) / stride + 1),
        };
        let out_shape = Shape::new(&[n, out_c, oh, ow]);
        let macs = (n * oh * ow * out_c * (in_c / groups) * kh * kw) as u64;
        let params = (out_c * (in_c / groups) * kh * kw) as u64;
        let bytes = 4 * (in_shape.numel() + out_shape.numel() + params as usize) as u64;
        self.push(
            OpKind::Conv2d { kernel, stride, groups },
            out_shape,
            &[from],
            Cost { macs, flops: 2 * macs, bytes, params },
        )
    }

    /// Batch normalization (inference form: scale + shift).
    pub fn bn(&mut self, from: NodeId) -> NodeId {
        let shape = self.shape(from).clone();
        let c = shape.dim(1);
        let numel = shape.numel() as u64;
        self.push(
            OpKind::BatchNorm,
            shape,
            &[from],
            Cost { macs: 0, flops: 2 * numel, bytes: 8 * numel, params: 2 * c as u64 },
        )
    }

    /// Layer normalization over the last dim.
    pub fn layernorm(&mut self, from: NodeId) -> NodeId {
        let shape = self.shape(from).clone();
        let h = *shape.0.last().unwrap();
        let numel = shape.numel() as u64;
        self.push(
            OpKind::LayerNorm,
            shape,
            &[from],
            Cost { macs: 0, flops: 8 * numel, bytes: 8 * numel, params: 2 * h as u64 },
        )
    }

    /// Elementwise unary activation.
    pub fn act(&mut self, from: NodeId, kind: OpKind) -> NodeId {
        debug_assert!(matches!(
            kind,
            OpKind::ReLU
                | OpKind::ReLU6
                | OpKind::Sigmoid
                | OpKind::Swish
                | OpKind::GeLU
                | OpKind::Tanh
        ));
        let shape = self.shape(from).clone();
        let numel = shape.numel() as u64;
        self.push(kind, shape, &[from], Cost { macs: 0, flops: numel, bytes: 8 * numel, params: 0 })
    }

    pub fn relu(&mut self, from: NodeId) -> NodeId {
        self.act(from, OpKind::ReLU)
    }

    /// conv → bn → relu, the CNN workhorse.
    pub fn conv_bn_relu(&mut self, from: NodeId, out_c: usize, k: usize, stride: usize) -> NodeId {
        let c = self.conv(from, out_c, k, stride);
        let b = self.bn(c);
        self.relu(b)
    }

    /// conv → bn (no activation; residual tails).
    pub fn conv_bn(&mut self, from: NodeId, out_c: usize, k: usize, stride: usize) -> NodeId {
        let c = self.conv(from, out_c, k, stride);
        self.bn(c)
    }

    /// NASNet-style separable conv: (relu → dw k×k → pw 1×1 → bn) applied
    /// twice — the small-kernel pattern that makes NAS nets launch-bound.
    pub fn sep_conv(&mut self, from: NodeId, out_c: usize, k: usize, stride: usize) -> NodeId {
        let mut x = self.relu(from);
        x = self.dwconv(x, k, stride);
        x = self.conv(x, out_c, 1, 1);
        x = self.bn(x);
        x = self.relu(x);
        x = self.dwconv(x, k, 1);
        x = self.conv(x, out_c, 1, 1);
        self.bn(x)
    }

    /// Elementwise binary op (shapes must match; SE gates broadcast is
    /// accounted as full-size traffic).
    fn binary(&mut self, kind: OpKind, a: NodeId, b: NodeId) -> NodeId {
        let (sa, sb) = (self.shape(a), self.shape(b));
        assert!(
            sa == sb || sb.numel() < sa.numel(),
            "binary {kind:?} shape mismatch: {sa} vs {sb}"
        );
        let shape = self.shape(a).clone();
        let numel = shape.numel() as u64;
        self.push(kind, shape, &[a, b], Cost { macs: 0, flops: numel, bytes: 12 * numel, params: 0 })
    }

    pub fn add(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary(OpKind::Add, a, b)
    }

    pub fn mul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary(OpKind::Mul, a, b)
    }

    /// Channel concat (NCHW dim 1).
    pub fn concat(&mut self, inputs: &[NodeId]) -> NodeId {
        assert!(!inputs.is_empty());
        let first = self.shape(inputs[0]).clone();
        let c: usize = inputs.iter().map(|&i| self.channels(i)).sum();
        let out = Shape::new(&[first.dim(0), c, first.dim(2), first.dim(3)]);
        let numel = out.numel() as u64;
        self.push(
            OpKind::Concat,
            out,
            inputs,
            Cost { macs: 0, flops: 0, bytes: 8 * numel, params: 0 },
        )
    }

    fn pool(&mut self, kind: OpKind, from: NodeId, k: usize, stride: usize, pad: Pad) -> NodeId {
        let s = self.shape(from).clone();
        let (h, w) = (s.dim(2), s.dim(3));
        let (oh, ow) = match pad {
            Pad::Same => (ceil_div(h, stride), ceil_div(w, stride)),
            Pad::Valid => ((h - k) / stride + 1, (w - k) / stride + 1),
        };
        let out = Shape::new(&[s.dim(0), s.dim(1), oh, ow]);
        let flops = (out.numel() * k * k) as u64;
        let bytes = 4 * (s.numel() + out.numel()) as u64;
        self.push(kind, out, &[from], Cost { macs: 0, flops, bytes, params: 0 })
    }

    pub fn maxpool(&mut self, from: NodeId, k: usize, stride: usize) -> NodeId {
        self.pool(OpKind::MaxPool { kernel: k, stride }, from, k, stride, Pad::Same)
    }

    pub fn maxpool_valid(&mut self, from: NodeId, k: usize, stride: usize) -> NodeId {
        self.pool(OpKind::MaxPool { kernel: k, stride }, from, k, stride, Pad::Valid)
    }

    pub fn avgpool(&mut self, from: NodeId, k: usize, stride: usize) -> NodeId {
        self.pool(OpKind::AvgPool { kernel: k, stride }, from, k, stride, Pad::Same)
    }

    /// Global average pool to (N, C, 1, 1).
    pub fn gap(&mut self, from: NodeId) -> NodeId {
        let s = self.shape(from).clone();
        let out = Shape::new(&[s.dim(0), s.dim(1), 1, 1]);
        let bytes = 4 * (s.numel() + out.numel()) as u64;
        self.push(
            OpKind::GlobalAvgPool,
            out,
            &[from],
            Cost { macs: 0, flops: s.numel() as u64, bytes, params: 0 },
        )
    }

    /// Fully connected layer. Rank-3 inputs (B, S, H) are projected
    /// per-token to (B, S, out); rank-2/rank-4 inputs are flattened to
    /// (N, out) (classifier heads on pooled features).
    pub fn linear(&mut self, from: NodeId, out_features: usize) -> NodeId {
        let s = self.shape(from).clone();
        let (rows, in_features, out) = if s.rank() == 3 {
            let (b_, s_, h) = (s.dim(0), s.dim(1), s.dim(2));
            (b_ * s_, h, Shape::new(&[b_, s_, out_features]))
        } else {
            let n = s.dim(0);
            (n, s.numel() / n, Shape::new(&[n, out_features]))
        };
        let macs = (rows * in_features * out_features) as u64;
        let params = (in_features * out_features + out_features) as u64;
        let bytes = 4 * (s.numel() + out.numel() + params as usize) as u64;
        self.push(OpKind::Linear, out, &[from], Cost { macs, flops: 2 * macs, bytes, params })
    }

    /// Free reshape/view (no GPU task; keeps shapes explicit in the graph).
    pub fn reshape(&mut self, from: NodeId, dims: &[usize]) -> NodeId {
        let out = Shape::new(dims);
        assert_eq!(out.numel(), self.shape(from).numel(), "reshape numel mismatch");
        let name = self.next_name("id");
        let id = self.g.add_node(Op::virtual_op(name, OpKind::Identity, out));
        self.g.add_edge(from, id);
        id
    }

    /// Batched matmul with explicit result shape: (b, m, k) × (b, k, n).
    pub fn matmul(&mut self, a: NodeId, b: NodeId, out_dims: &[usize], mnk: (usize, usize, usize)) -> NodeId {
        let out = Shape::new(out_dims);
        let batch: usize = out_dims[..out_dims.len() - 2].iter().product();
        let (m, n, k) = mnk;
        let macs = (batch * m * n * k) as u64;
        let bytes = 4 * (self.shape(a).numel() + self.shape(b).numel() + out.numel()) as u64;
        self.push(OpKind::MatMul, out, &[a, b], Cost { macs, flops: 2 * macs, bytes, params: 0 })
    }

    /// Softmax over the last dim.
    pub fn softmax(&mut self, from: NodeId) -> NodeId {
        let shape = self.shape(from).clone();
        let numel = shape.numel() as u64;
        self.push(
            OpKind::Softmax,
            shape,
            &[from],
            Cost { macs: 0, flops: 5 * numel, bytes: 8 * numel, params: 0 },
        )
    }

    /// Token embedding lookup producing (B, S, H).
    pub fn embedding(&mut self, from: NodeId, hidden: usize, vocab: usize) -> NodeId {
        let s = self.shape(from).clone();
        let out = Shape::new(&[s.dim(0), s.dim(1), hidden]);
        let bytes = 4 * out.numel() as u64;
        self.push(
            OpKind::Embedding,
            out,
            &[from],
            Cost { macs: 0, flops: 0, bytes, params: (vocab * hidden) as u64 },
        )
    }

    /// Channel slice of an NCHW tensor (MixConv-style group split): a view
    /// on GPU, so modelled as a virtual op.
    pub fn slice_channels(&mut self, from: NodeId, channels: usize) -> NodeId {
        let s = self.shape(from).clone();
        assert!(channels <= s.dim(1), "slice wider than tensor");
        let out = Shape::new(&[s.dim(0), channels, s.dim(2), s.dim(3)]);
        let name = self.next_name("id");
        let id = self.g.add_node(Op::virtual_op(name, OpKind::Identity, out));
        self.g.add_edge(from, id);
        id
    }

    /// Zero-cost identity/reshape node (keeps branch topology explicit).
    pub fn identity(&mut self, from: NodeId) -> NodeId {
        let shape = self.shape(from).clone();
        let name = self.next_name("id");
        let id = self.g.add_node(Op::virtual_op(name, OpKind::Identity, shape));
        self.g.add_edge(from, id);
        id
    }

    /// Access the graph under construction (e.g. to read shapes).
    pub fn graph(&self) -> &OpGraph {
        &self.g
    }

    pub fn out_shape(&self, id: NodeId) -> &Shape {
        self.shape(id)
    }

    /// Finish building.
    pub fn finish(self) -> OpGraph {
        debug_assert!(self.g.validate().is_ok());
        self.g
    }
}

impl Default for GraphBuilder {
    fn default() -> Self {
        Self::new()
    }
}

struct Cost {
    macs: u64,
    flops: u64,
    bytes: u64,
    params: u64,
}

/// Padding mode for convs/pools.
#[derive(Debug, Clone, Copy)]
enum Pad {
    Same,
    Valid,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::op::total_macs;

    #[test]
    fn conv_shape_and_macs() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 224, 224]);
        let c = b.conv(x, 64, 7, 2);
        assert_eq!(b.out_shape(c), &Shape::new(&[1, 64, 112, 112]));
        // 112*112*64*3*7*7
        assert_eq!(b.graph().node(c).macs, 112 * 112 * 64 * 3 * 49);
    }

    #[test]
    fn dwconv_macs_divide_by_groups() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 32, 56, 56]);
        let d = b.dwconv(x, 3, 1);
        assert_eq!(b.out_shape(d), &Shape::new(&[1, 32, 56, 56]));
        assert_eq!(b.graph().node(d).macs, 56 * 56 * 32 * 9);
    }

    #[test]
    fn linear_from_pooled() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 2048, 7, 7]);
        let g = b.gap(x);
        let f = b.linear(g, 1000);
        assert_eq!(b.out_shape(f), &Shape::new(&[1, 1000]));
        assert_eq!(b.graph().node(f).macs, 2048 * 1000);
    }

    #[test]
    fn concat_sums_channels() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 16, 8, 8]);
        let c1 = b.conv(x, 8, 1, 1);
        let c2 = b.conv(x, 24, 3, 1);
        let cat = b.concat(&[c1, c2]);
        assert_eq!(b.out_shape(cat), &Shape::new(&[1, 32, 8, 8]));
        assert_eq!(b.graph().predecessors(cat).len(), 2);
    }

    #[test]
    fn sep_conv_op_count_and_stride() {
        // 2 × (relu + dw + pw + bn) = 8 ops
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 44, 28, 28]);
        let before = b.graph().n_nodes();
        let s = b.sep_conv(x, 44, 5, 2);
        assert_eq!(b.graph().n_nodes() - before, 8);
        assert_eq!(b.out_shape(s), &Shape::new(&[1, 44, 14, 14]));
    }

    #[test]
    fn matmul_macs() {
        let mut b = GraphBuilder::new();
        let q = b.input(&[12, 128, 64]);
        let k = b.input(&[12, 64, 128]);
        let s = b.matmul(q, k, &[12, 128, 128], (128, 128, 64));
        assert_eq!(b.graph().node(s).macs, 12 * 128 * 128 * 64);
    }

    #[test]
    fn total_macs_accumulates() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 32, 32]);
        let c = b.conv_bn_relu(x, 16, 3, 1);
        let _f = b.linear(c, 10);
        let g = b.finish();
        assert!(total_macs(&g) > 0);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn builder_graph_is_connected_dag() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 8, 16, 16]);
        let a1 = b.conv_bn_relu(x, 8, 3, 1);
        let a2 = b.conv_bn_relu(x, 8, 5, 1);
        let m = b.add(a1, a2);
        let g = b.finish();
        assert!(g.validate().is_ok());
        assert_eq!(g.sinks(), vec![m]);
    }
}
