//! Operator definitions: kinds, shapes, and cost metadata.
//!
//! Every node of a computation graph is an [`Op`]: a DL operator with its
//! output shape and precomputed cost metadata (MACs, FLOPs, memory traffic,
//! parameter count). The costs feed the simulator's roofline kernel model
//! (`sim::cost`) and the #MACs column of Table 1.

use crate::graph::Dag;

/// A computation graph of operators.
pub type OpGraph = Dag<Op>;

/// Element dtype. The paper's evaluation is fp32 on V100 (no tensor-core
/// path is claimed); fp16/bf16 are carried for the cost model's MXU path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum DType {
    #[default]
    F32,
    F16,
    BF16,
    I32,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F16 | DType::BF16 => 2,
        }
    }
}

/// Tensor shape (row-major dims; NCHW for images, (B, S, H) for sequences).
#[derive(Debug, Clone, PartialEq, Eq, Hash, Default)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn new(dims: &[usize]) -> Self {
        Shape(dims.to_vec())
    }

    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    pub fn rank(&self) -> usize {
        self.0.len()
    }

    pub fn dim(&self, i: usize) -> usize {
        self.0[i]
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}]", self.0.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(","))
    }
}

/// Operator kind. Structural parameters that affect cost live here; channel
/// counts are derived from the input/output shapes when costs are computed.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Graph input placeholder (no GPU task).
    Input,
    /// 2D convolution (`groups == in_c` for depthwise). `kernel` is
    /// (kh, kw) — Inception-v3 uses rectangular 1×7 / 7×1 factorizations.
    Conv2d { kernel: (usize, usize), stride: usize, groups: usize },
    /// Fully connected / dense layer.
    Linear,
    /// Batched matrix multiply (transformers).
    MatMul,
    BatchNorm,
    LayerNorm,
    ReLU,
    ReLU6,
    Sigmoid,
    Swish,
    GeLU,
    Tanh,
    Softmax,
    /// Elementwise addition (residual connections, cell combines).
    Add,
    /// Elementwise multiply (SE gates, attention masks).
    Mul,
    /// Channel concatenation.
    Concat,
    MaxPool { kernel: usize, stride: usize },
    AvgPool { kernel: usize, stride: usize },
    GlobalAvgPool,
    Embedding,
    /// Memory-movement only (reshape/transpose/identity/pad).
    Identity,
    /// Result of the fusion pass: a chain of ops executed as one kernel.
    Fused { parts: Vec<OpKind> },
    /// Backward counterpart of an op (training graphs).
    Grad { of: Box<OpKind> },
    /// Optimizer update for one parameter tensor (training graphs).
    OptimizerStep,
}

impl OpKind {
    /// Short mnemonic for labels and dispatch keys.
    pub fn mnemonic(&self) -> String {
        match self {
            OpKind::Input => "input".into(),
            OpKind::Conv2d { kernel: (kh, kw), stride, groups } => {
                if *groups > 1 {
                    format!("dwconv{kh}x{kw}s{stride}")
                } else {
                    format!("conv{kh}x{kw}s{stride}")
                }
            }
            OpKind::Linear => "linear".into(),
            OpKind::MatMul => "matmul".into(),
            OpKind::BatchNorm => "bn".into(),
            OpKind::LayerNorm => "ln".into(),
            OpKind::ReLU => "relu".into(),
            OpKind::ReLU6 => "relu6".into(),
            OpKind::Sigmoid => "sigmoid".into(),
            OpKind::Swish => "swish".into(),
            OpKind::GeLU => "gelu".into(),
            OpKind::Tanh => "tanh".into(),
            OpKind::Softmax => "softmax".into(),
            OpKind::Add => "add".into(),
            OpKind::Mul => "mul".into(),
            OpKind::Concat => "concat".into(),
            OpKind::MaxPool { kernel, .. } => format!("maxpool{kernel}"),
            OpKind::AvgPool { kernel, .. } => format!("avgpool{kernel}"),
            OpKind::GlobalAvgPool => "gap".into(),
            OpKind::Embedding => "embed".into(),
            OpKind::Identity => "id".into(),
            OpKind::Fused { parts } => {
                let inner: Vec<String> = parts.iter().map(|p| p.mnemonic()).collect();
                format!("fused[{}]", inner.join("+"))
            }
            OpKind::Grad { of } => format!("grad_{}", of.mnemonic()),
            OpKind::OptimizerStep => "sgd".into(),
        }
    }

    /// Whether the op is compute-bound matrix math (MXU/TensorCore path).
    pub fn is_matmul_like(&self) -> bool {
        matches!(
            self,
            OpKind::Conv2d { .. } | OpKind::Linear | OpKind::MatMul
        ) || matches!(self, OpKind::Fused { parts } if parts.iter().any(|p| p.is_matmul_like()))
            || matches!(self, OpKind::Grad { of } if of.is_matmul_like())
    }

    /// Whether the op launches no GPU task (inputs, identities).
    pub fn is_virtual(&self) -> bool {
        matches!(self, OpKind::Input | OpKind::Identity)
    }
}

/// A DL operator node: kind + output shape + cost metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct Op {
    pub name: String,
    pub kind: OpKind,
    pub out_shape: Shape,
    pub dtype: DType,
    /// Multiply-accumulate count (the paper's "#MACs").
    pub macs: u64,
    /// Total floating-point ops (≈ 2·MACs for matmul-likes, elementwise count
    /// for the rest).
    pub flops: u64,
    /// Bytes read from + written to device memory.
    pub bytes: u64,
    /// Parameter (weight) element count.
    pub params: u64,
}

impl Op {
    /// A zero-cost placeholder op (inputs, identities).
    pub fn virtual_op(name: impl Into<String>, kind: OpKind, out_shape: Shape) -> Self {
        Op {
            name: name.into(),
            kind,
            out_shape,
            dtype: DType::F32,
            macs: 0,
            flops: 0,
            bytes: 0,
            params: 0,
        }
    }

    /// Dispatch key used by the (simulated and real) kernel dispatchers —
    /// the paper's run-time scheduler re-derives this on every execution;
    /// Nimble resolves it once during the AoT pre-run.
    pub fn dispatch_key(&self) -> String {
        format!("{}:{:?}:{}", self.kind.mnemonic(), self.dtype, self.out_shape)
    }
}

/// Sum of MACs over a graph (Table 1's "#MACs" column).
pub fn total_macs(g: &OpGraph) -> u64 {
    g.nodes().map(|(_, op)| op.macs).sum()
}

/// Number of GPU-task-launching ops (excludes Input/Identity).
pub fn n_real_ops(g: &OpGraph) -> usize {
    g.nodes().filter(|(_, op)| !op.kind.is_virtual()).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::BF16.size_bytes(), 2);
    }

    #[test]
    fn shape_numel_and_display() {
        let s = Shape::new(&[1, 3, 224, 224]);
        assert_eq!(s.numel(), 150_528);
        assert_eq!(s.to_string(), "[1,3,224,224]");
        assert_eq!(s.rank(), 4);
    }

    #[test]
    fn mnemonics() {
        assert_eq!(
            OpKind::Conv2d { kernel: (3, 3), stride: 1, groups: 1 }.mnemonic(),
            "conv3x3s1"
        );
        assert_eq!(
            OpKind::Conv2d { kernel: (5, 5), stride: 2, groups: 32 }.mnemonic(),
            "dwconv5x5s2"
        );
        assert_eq!(
            OpKind::Conv2d { kernel: (1, 7), stride: 1, groups: 1 }.mnemonic(),
            "conv1x7s1"
        );
        let f = OpKind::Fused { parts: vec![OpKind::BatchNorm, OpKind::ReLU] };
        assert_eq!(f.mnemonic(), "fused[bn+relu]");
    }

    #[test]
    fn matmul_like_classification() {
        assert!(OpKind::Linear.is_matmul_like());
        assert!(OpKind::Conv2d { kernel: (1, 1), stride: 1, groups: 1 }.is_matmul_like());
        assert!(!OpKind::ReLU.is_matmul_like());
        let f = OpKind::Fused {
            parts: vec![OpKind::Conv2d { kernel: (3, 3), stride: 1, groups: 1 }, OpKind::ReLU],
        };
        assert!(f.is_matmul_like());
        let g = OpKind::Grad { of: Box::new(OpKind::Linear) };
        assert!(g.is_matmul_like());
    }

    #[test]
    fn dispatch_key_distinguishes_shapes() {
        let mut a = Op::virtual_op("x", OpKind::ReLU, Shape::new(&[1, 8]));
        let mut b = a.clone();
        b.out_shape = Shape::new(&[1, 16]);
        a.kind = OpKind::ReLU;
        assert_ne!(a.dispatch_key(), b.dispatch_key());
    }
}
