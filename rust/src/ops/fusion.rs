//! Operator fusion — "we also implement the operator fusion (a subset of
//! TensorRT's)" (paper §5, Experimental Setup).
//!
//! Two patterns, applied greedily on single-consumer chains:
//!   1. matmul-like + epilogue: Conv/Linear followed by BatchNorm and/or an
//!      activation collapses into one kernel (the epilogue is free — it runs
//!      out of registers/VMEM while the tile is resident).
//!   2. elementwise chains: consecutive unary elementwise ops merge.
//!
//! Fusion preserves the dependency structure: the fused node inherits all
//! external predecessors/successors of its parts. MACs add; bytes take the
//! chain's external traffic only (intermediate tensors never hit HBM —
//! that is the point of fusing).

use super::op::{Op, OpGraph, OpKind};
use crate::graph::NodeId;

/// Is `k` an epilogue op that can ride on a matmul-like kernel?
fn is_epilogue(k: &OpKind) -> bool {
    matches!(
        k,
        OpKind::BatchNorm
            | OpKind::ReLU
            | OpKind::ReLU6
            | OpKind::Sigmoid
            | OpKind::Swish
            | OpKind::GeLU
            | OpKind::Tanh
    )
}

/// Is `k` a fusable elementwise op? (`Add`/`Mul` as chain *heads* model
/// TensorRT's residual-add+activation fusion; they can absorb a following
/// unary but are never absorbed themselves — they have multiple inputs.)
fn is_elementwise(k: &OpKind) -> bool {
    is_epilogue(k) || matches!(k, OpKind::LayerNorm | OpKind::Softmax | OpKind::Add | OpKind::Mul)
}

/// Apply the fusion pass, returning a new graph. Node ids are NOT stable
/// across fusion; the result is a fresh graph.
pub fn fuse_graph(g: &OpGraph) -> OpGraph {
    let n = g.n_nodes();
    // Greedy chain construction: walk in topo order; a node joins its
    // predecessor's chain if it is that predecessor's only consumer and the
    // pattern allows it.
    let order = crate::graph::topo_order(g).expect("fusion requires a DAG");
    let mut chain_of: Vec<usize> = (0..n).collect(); // chain representative
    for &v in &order {
        let op = g.node(v);
        if g.predecessors(v).len() != 1 {
            continue;
        }
        let p = g.predecessors(v)[0];
        if g.successors(p).len() != 1 {
            continue; // predecessor has other consumers; cannot absorb
        }
        let head = chain_of[p];
        let head_kind = &g.node(head).kind;
        let can_fuse = if head_kind.is_matmul_like() || matches!(head_kind, OpKind::Fused { .. }) {
            is_epilogue(&op.kind)
        } else {
            is_elementwise(head_kind) && is_elementwise(&op.kind)
        };
        // Never fuse across virtual nodes.
        if can_fuse && !op.kind.is_virtual() && !g.node(p).kind.is_virtual() {
            chain_of[v] = head;
        }
    }

    // Collect chains in head order.
    let mut members: Vec<Vec<NodeId>> = vec![Vec::new(); n];
    for &v in &order {
        members[chain_of[v]].push(v);
    }

    // Build the fused graph.
    let mut out = OpGraph::new();
    let mut new_id = vec![usize::MAX; n];
    for &head in &order {
        let chain = &members[head];
        if chain.is_empty() {
            continue;
        }
        let fused_op = if chain.len() == 1 {
            g.node(chain[0]).clone()
        } else {
            let parts: Vec<OpKind> = chain.iter().map(|&v| g.node(v).kind.clone()).collect();
            let last = g.node(*chain.last().unwrap());
            let macs: u64 = chain.iter().map(|&v| g.node(v).macs).sum();
            let flops: u64 = chain.iter().map(|&v| g.node(v).flops).sum();
            let params: u64 = chain.iter().map(|&v| g.node(v).params).sum();
            // external traffic: head's inputs + tail's output + params
            let head_op = g.node(chain[0]);
            let in_bytes: u64 = g
                .predecessors(chain[0])
                .iter()
                .map(|&p| 4 * g.node(p).out_shape.numel() as u64)
                .sum();
            let bytes = in_bytes + 4 * last.out_shape.numel() as u64 + 4 * params;
            Op {
                name: format!("{}_fused", head_op.name),
                kind: OpKind::Fused { parts },
                out_shape: last.out_shape.clone(),
                dtype: last.dtype,
                macs,
                flops,
                bytes,
                params,
            }
        };
        let id = out.add_node(fused_op);
        for &v in chain {
            new_id[v] = id;
        }
    }
    // Edges: external edges between chains.
    for (u, v) in g.edges() {
        let (nu, nv) = (new_id[u], new_id[v]);
        if nu != nv {
            out.add_edge(nu, nv);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::builder::GraphBuilder;
    use crate::ops::op::{n_real_ops, total_macs};

    #[test]
    fn conv_bn_relu_fuses_to_one() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 32, 32]);
        let _ = b.conv_bn_relu(x, 16, 3, 1);
        let g = b.finish();
        let f = fuse_graph(&g);
        // input + fused(conv,bn,relu)
        assert_eq!(f.n_nodes(), 2);
        let fused = f.nodes().find(|(_, o)| matches!(o.kind, OpKind::Fused { .. })).unwrap();
        if let OpKind::Fused { parts } = &fused.1.kind {
            assert_eq!(parts.len(), 3);
        }
    }

    #[test]
    fn fusion_preserves_macs() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 64, 64]);
        let c1 = b.conv_bn_relu(x, 32, 3, 2);
        let c2 = b.conv_bn_relu(c1, 64, 3, 2);
        let _ = b.linear(c2, 10);
        let g = b.finish();
        let f = fuse_graph(&g);
        assert_eq!(total_macs(&g), total_macs(&f));
        assert!(f.n_nodes() < g.n_nodes());
    }

    #[test]
    fn fusion_reduces_bytes() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 64, 64]);
        let _ = b.conv_bn_relu(x, 32, 3, 1);
        let g = b.finish();
        let f = fuse_graph(&g);
        let gb: u64 = g.nodes().map(|(_, o)| o.bytes).sum();
        let fb: u64 = f.nodes().map(|(_, o)| o.bytes).sum();
        assert!(fb < gb, "fused traffic {fb} should be < unfused {gb}");
    }

    #[test]
    fn does_not_fuse_across_branches() {
        // conv feeding two consumers must stay unfused with them.
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 8, 16, 16]);
        let c = b.conv(x, 8, 3, 1);
        let r1 = b.relu(c);
        let r2 = b.act(c, OpKind::Sigmoid);
        let _ = b.add(r1, r2);
        let g = b.finish();
        let f = fuse_graph(&g);
        // conv kept separate (2 consumers): input, conv, relu, sigmoid, add
        assert_eq!(f.n_nodes(), 5);
    }

    #[test]
    fn fused_graph_is_valid_dag() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 16, 28, 28]);
        let s = b.sep_conv(x, 32, 3, 1);
        let t = b.sep_conv(x, 32, 5, 1);
        let _ = b.add(s, t);
        let g = b.finish();
        let f = fuse_graph(&g);
        assert!(f.validate().is_ok());
        assert_eq!(total_macs(&g), total_macs(&f));
        assert!(n_real_ops(&f) < n_real_ops(&g));
    }
}
