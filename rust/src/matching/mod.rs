//! Maximum bipartite matching (Steps 2–3 of Algorithm 1).
//!
//! The paper computes a maximum matching of the bipartite graph
//! `B = (V₁, V₂, E_B)` derived from the MEG with Ford–Fulkerson. We provide
//! Ford–Fulkerson (the paper's choice, simple and O(V·E)) and Hopcroft–Karp
//! (O(E·√V), the production default) and cross-check them in tests — both
//! return matchings of identical (maximum) cardinality.

pub mod bipartite;
pub mod ford_fulkerson;
pub mod hopcroft_karp;

pub use bipartite::{BipartiteGraph, Matching};
pub use ford_fulkerson::ford_fulkerson;
pub use hopcroft_karp::hopcroft_karp;

/// The algorithm used to compute a maximum matching.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MatchingAlgo {
    /// Hopcroft–Karp, O(E·√V). Default.
    #[default]
    HopcroftKarp,
    /// Ford–Fulkerson via repeated augmenting DFS, O(V·E). The paper's choice.
    FordFulkerson,
}

/// Compute a maximum matching with the selected algorithm.
pub fn maximum_matching(b: &BipartiteGraph, algo: MatchingAlgo) -> Matching {
    match algo {
        MatchingAlgo::HopcroftKarp => hopcroft_karp(b),
        MatchingAlgo::FordFulkerson => ford_fulkerson(b),
    }
}
