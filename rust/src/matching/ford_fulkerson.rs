//! Ford–Fulkerson maximum bipartite matching via augmenting-path DFS.
//!
//! This is the algorithm the paper names (citing Ford & Fulkerson 1956): unit
//! capacities reduce max-flow to repeated augmenting-path search, O(V·E).
//! Kept alongside Hopcroft–Karp both as the faithful-to-paper implementation
//! and as a differential-testing oracle.

use super::bipartite::{BipartiteGraph, Matching};

/// Compute a maximum matching by repeatedly augmenting from each unmatched
/// left vertex.
pub fn ford_fulkerson(g: &BipartiteGraph) -> Matching {
    let mut m = Matching::empty(g.n_left(), g.n_right());
    let mut visited = vec![false; g.n_right()];
    for l in 0..g.n_left() {
        visited.fill(false);
        let _ = augment(g, l, &mut visited, &mut m);
    }
    m
}

/// DFS for an augmenting path starting at left vertex `l`.
fn augment(g: &BipartiteGraph, l: usize, visited: &mut [bool], m: &mut Matching) -> bool {
    for &r in g.neighbours(l) {
        if visited[r] {
            continue;
        }
        visited[r] = true;
        // r is free, or its current partner can be re-matched elsewhere.
        let free = match m.right_to_left[r] {
            None => true,
            Some(l2) => augment(g, l2, visited, m),
        };
        if free {
            m.left_to_right[l] = Some(r);
            m.right_to_left[r] = Some(l);
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_matching_on_identity() {
        let mut g = BipartiteGraph::new(4, 4);
        for i in 0..4 {
            g.add_edge(i, i);
        }
        let m = ford_fulkerson(&g);
        assert_eq!(m.cardinality(), 4);
        m.validate(&g).unwrap();
    }

    #[test]
    fn requires_augmentation() {
        // Classic case where greedy fails without augmenting paths:
        // l0 -> {r0, r1}, l1 -> {r0}. Max matching is 2.
        let mut g = BipartiteGraph::new(2, 2);
        g.add_edge(0, 0);
        g.add_edge(0, 1);
        g.add_edge(1, 0);
        let m = ford_fulkerson(&g);
        assert_eq!(m.cardinality(), 2);
        m.validate(&g).unwrap();
    }

    #[test]
    fn empty_graph() {
        let g = BipartiteGraph::new(3, 3);
        let m = ford_fulkerson(&g);
        assert_eq!(m.cardinality(), 0);
        m.validate(&g).unwrap();
    }

    #[test]
    fn star_matches_one() {
        // One left vertex connected to all rights: cardinality 1.
        let mut g = BipartiteGraph::new(1, 5);
        for r in 0..5 {
            g.add_edge(0, r);
        }
        let m = ford_fulkerson(&g);
        assert_eq!(m.cardinality(), 1);
    }
}
