//! Hopcroft–Karp maximum bipartite matching, O(E·√V).
//!
//! Production default: Algorithm 1 runs once per model at engine-build time,
//! but large NAS graphs (NASNet-A large ≈ 1.3k operators) and the property
//! tests benefit from the better bound. Phases alternate a BFS that layers
//! free left vertices by shortest alternating distance and a DFS that
//! extracts a maximal set of vertex-disjoint shortest augmenting paths.

use super::bipartite::{BipartiteGraph, Matching};
use std::collections::VecDeque;

const INF: u32 = u32::MAX;

pub fn hopcroft_karp(g: &BipartiteGraph) -> Matching {
    let (nl, nr) = (g.n_left(), g.n_right());
    let mut m = Matching::empty(nl, nr);
    let mut dist = vec![INF; nl];
    let mut queue = VecDeque::new();

    loop {
        // BFS: layer free left vertices at distance 0.
        queue.clear();
        for l in 0..nl {
            if m.left_to_right[l].is_none() {
                dist[l] = 0;
                queue.push_back(l);
            } else {
                dist[l] = INF;
            }
        }
        let mut found_augmenting = false;
        while let Some(l) = queue.pop_front() {
            for &r in g.neighbours(l) {
                match m.right_to_left[r] {
                    None => found_augmenting = true,
                    Some(l2) => {
                        if dist[l2] == INF {
                            dist[l2] = dist[l] + 1;
                            queue.push_back(l2);
                        }
                    }
                }
            }
        }
        if !found_augmenting {
            break;
        }
        // DFS phase: extract disjoint shortest augmenting paths.
        for l in 0..nl {
            if m.left_to_right[l].is_none() {
                let _ = dfs(g, l, &mut dist, &mut m);
            }
        }
    }
    m
}

fn dfs(g: &BipartiteGraph, l: usize, dist: &mut [u32], m: &mut Matching) -> bool {
    for &r in g.neighbours(l) {
        let ok = match m.right_to_left[r] {
            None => true,
            Some(l2) => dist[l2] == dist[l] + 1 && dfs(g, l2, dist, m),
        };
        if ok {
            m.left_to_right[l] = Some(r);
            m.right_to_left[r] = Some(l);
            return true;
        }
    }
    dist[l] = INF; // dead end: prune for this phase
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matching::ford_fulkerson;
    use crate::util::{prop, Pcg32};

    #[test]
    fn perfect_matching_on_complete_graph() {
        let mut g = BipartiteGraph::new(5, 5);
        for l in 0..5 {
            for r in 0..5 {
                g.add_edge(l, r);
            }
        }
        let m = hopcroft_karp(&g);
        assert_eq!(m.cardinality(), 5);
        m.validate(&g).unwrap();
    }

    #[test]
    fn known_nontrivial_case() {
        // l0-{r0,r1}, l1-{r0}, l2-{r1,r2} -> max matching 3
        let mut g = BipartiteGraph::new(3, 3);
        g.add_edge(0, 0);
        g.add_edge(0, 1);
        g.add_edge(1, 0);
        g.add_edge(2, 1);
        g.add_edge(2, 2);
        let m = hopcroft_karp(&g);
        assert_eq!(m.cardinality(), 3);
    }

    #[test]
    fn asymmetric_sides() {
        let mut g = BipartiteGraph::new(2, 6);
        g.add_edge(0, 5);
        g.add_edge(1, 5);
        let m = hopcroft_karp(&g);
        assert_eq!(m.cardinality(), 1);
        m.validate(&g).unwrap();
    }

    #[test]
    fn agrees_with_ford_fulkerson_on_random_graphs() {
        prop::check("hk == ff cardinality", 60, |rng: &mut Pcg32| {
            let nl = rng.gen_range_inclusive(1, 25);
            let nr = rng.gen_range_inclusive(1, 25);
            let mut g = BipartiteGraph::new(nl, nr);
            for l in 0..nl {
                for r in 0..nr {
                    if rng.gen_bool(0.15) {
                        g.add_edge(l, r);
                    }
                }
            }
            let hk = hopcroft_karp(&g);
            let ff = ford_fulkerson(&g);
            hk.validate(&g)?;
            ff.validate(&g)?;
            prop::ensure(hk.cardinality() == ff.cardinality(), || {
                format!("hk={} ff={}", hk.cardinality(), ff.cardinality())
            })
        });
    }
}
