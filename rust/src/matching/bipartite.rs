//! Bipartite graph and matching representations.

/// A bipartite graph with `n_left` left vertices and `n_right` right
/// vertices; adjacency stored left-to-right.
#[derive(Debug, Clone)]
pub struct BipartiteGraph {
    n_left: usize,
    n_right: usize,
    adj: Vec<Vec<usize>>, // adj[l] = right neighbours of left vertex l
    n_edges: usize,
}

impl BipartiteGraph {
    pub fn new(n_left: usize, n_right: usize) -> Self {
        BipartiteGraph { n_left, n_right, adj: vec![Vec::new(); n_left], n_edges: 0 }
    }

    /// Build the Step-2 bipartite graph from a directed edge list over `n`
    /// nodes: edge (vᵢ, vⱼ) ∈ E' becomes (xᵢ, yⱼ) ∈ E_B.
    pub fn from_dag_edges(n: usize, edges: &[(usize, usize)]) -> Self {
        let mut b = BipartiteGraph::new(n, n);
        for &(u, v) in edges {
            b.add_edge(u, v);
        }
        b
    }

    pub fn add_edge(&mut self, l: usize, r: usize) {
        assert!(l < self.n_left && r < self.n_right, "edge out of range");
        if !self.adj[l].contains(&r) {
            self.adj[l].push(r);
            self.n_edges += 1;
        }
    }

    pub fn n_left(&self) -> usize {
        self.n_left
    }

    pub fn n_right(&self) -> usize {
        self.n_right
    }

    pub fn n_edges(&self) -> usize {
        self.n_edges
    }

    pub fn neighbours(&self, l: usize) -> &[usize] {
        &self.adj[l]
    }
}

/// A matching: `left_to_right[l] = Some(r)` iff edge (l, r) is matched.
/// Maintained together with the inverse map.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Matching {
    pub left_to_right: Vec<Option<usize>>,
    pub right_to_left: Vec<Option<usize>>,
}

impl Matching {
    pub fn empty(n_left: usize, n_right: usize) -> Self {
        Matching { left_to_right: vec![None; n_left], right_to_left: vec![None; n_right] }
    }

    /// Number of matched edges.
    pub fn cardinality(&self) -> usize {
        self.left_to_right.iter().filter(|m| m.is_some()).count()
    }

    /// Matched edges as (left, right) pairs.
    pub fn edges(&self) -> Vec<(usize, usize)> {
        self.left_to_right
            .iter()
            .enumerate()
            .filter_map(|(l, r)| r.map(|r| (l, r)))
            .collect()
    }

    /// Validate matching invariants against a graph: every matched edge
    /// exists, and no vertex is matched twice (checked structurally).
    pub fn validate(&self, g: &BipartiteGraph) -> Result<(), String> {
        if self.left_to_right.len() != g.n_left() || self.right_to_left.len() != g.n_right() {
            return Err("matching size mismatch".into());
        }
        for (l, r) in self.edges() {
            if !g.neighbours(l).contains(&r) {
                return Err(format!("matched edge ({l},{r}) not in graph"));
            }
            if self.right_to_left[r] != Some(l) {
                return Err(format!("inverse map inconsistent at ({l},{r})"));
            }
        }
        let matched_rights: usize = self.right_to_left.iter().filter(|m| m.is_some()).count();
        if matched_rights != self.cardinality() {
            return Err("left/right matched counts differ".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_query() {
        let mut b = BipartiteGraph::new(3, 2);
        b.add_edge(0, 1);
        b.add_edge(2, 0);
        b.add_edge(2, 0); // duplicate ignored
        assert_eq!(b.n_edges(), 2);
        assert_eq!(b.neighbours(2), &[0]);
    }

    #[test]
    fn from_dag_edges_shape() {
        let b = BipartiteGraph::from_dag_edges(4, &[(0, 1), (1, 3)]);
        assert_eq!(b.n_left(), 4);
        assert_eq!(b.n_right(), 4);
        assert_eq!(b.neighbours(1), &[3]);
    }

    #[test]
    fn matching_validate_catches_phantom_edge() {
        let b = BipartiteGraph::from_dag_edges(2, &[(0, 1)]);
        let mut m = Matching::empty(2, 2);
        m.left_to_right[1] = Some(0);
        m.right_to_left[0] = Some(1);
        assert!(m.validate(&b).is_err());
    }

    #[test]
    fn matching_cardinality_and_edges() {
        let mut m = Matching::empty(3, 3);
        m.left_to_right[0] = Some(2);
        m.right_to_left[2] = Some(0);
        assert_eq!(m.cardinality(), 1);
        assert_eq!(m.edges(), vec![(0, 2)]);
    }
}
