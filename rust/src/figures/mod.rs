//! Figure/table regeneration harness: one generator per experiment in the
//! paper's evaluation (§3 Fig. 2, §5 Fig. 7 / Table 1 / Fig. 8, App. C
//! Fig. 9, App. D Fig. 10). Each prints the same rows/series the paper
//! reports, next to the paper's own numbers where the text states them,
//! and writes machine-readable TSV under `results/`.
//!
//! Absolute numbers come from the VGPU substrate (DESIGN.md §Hardware-
//! Adaptation); the claims under test are the *shapes*: who wins, by
//! roughly what factor, where the crossovers fall.

use crate::baselines::{simulate_inference, simulate_training, Baseline};
use crate::models;
use crate::ops::op::total_macs;
use crate::sim::metrics::{critical_path_s, total_kernel_s};
use crate::sim::GpuSpec;
use crate::stream::logical_concurrency_degree;
use crate::util::table::Table;
use std::path::Path;

/// The Fig. 2a / Fig. 7 model line-up.
const FIG7_MODELS: &[&str] = &[
    "resnet50",
    "resnet101",
    "inception_v3",
    "mobilenet_v2",
    "nasnet_a_mobile",
    "nasnet_a_large",
    "efficientnet_b0",
    "efficientnet_b5",
];

fn fmt_x(v: f64) -> String {
    format!("{v:.2}x")
}

fn fmt_pct(v: f64) -> String {
    format!("{:.0}%", v * 100.0)
}

/// Fig. 2a: ratio of GPU active time to overall running time, inference
/// batch 1, TensorFlow & PyTorch. Paper: GPUs idle up to 71% (TF) and 91%
/// (PyTorch).
pub fn fig2a() -> Table {
    let dev = GpuSpec::v100();
    let mut t = Table::new(vec!["model", "PyTorch active", "TensorFlow active", "paper note"]);
    let models_2a =
        ["resnet50", "inception_v3", "mobilenet_v2", "nasnet_a_mobile", "efficientnet_b0"];
    for name in models_2a {
        let g = models::build(name, 1);
        let pt = simulate_inference(&g, Baseline::PyTorch, &dev);
        let tf = simulate_inference(&g, Baseline::TensorFlow, &dev);
        let note = match name {
            "efficientnet_b0" => "paper: idle up to 91% (PT) / 71% (TF)",
            _ => "",
        };
        t.row(vec![
            name.to_string(),
            fmt_pct(pt.active_ratio()),
            fmt_pct(tf.active_ratio()),
            note.to_string(),
        ]);
    }
    t
}

/// Fig. 2b: PyTorch vs its scheduling-minimized version (same kernels,
/// hardcoded shapes/addresses). Paper: 2.37× on ResNet-50.
pub fn fig2b() -> Table {
    let dev = GpuSpec::v100();
    let mut t = Table::new(vec![
        "model",
        "PyTorch (ms)",
        "sched-minimized (ms)",
        "speedup",
        "paper",
    ]);
    for (name, paper) in [("resnet50", Some(2.37)), ("inception_v3", None)] {
        let g = models::build(name, 1);
        let pt = simulate_inference(&g, Baseline::PyTorch, &dev).total_s;
        let sm = simulate_inference(&g, Baseline::SchedMinimized, &dev).total_s;
        t.row(vec![
            name.to_string(),
            format!("{:.2}", pt * 1e3),
            format!("{:.2}", sm * 1e3),
            fmt_x(pt / sm),
            paper.map(fmt_x).unwrap_or_else(|| "—".into()),
        ]);
    }
    t
}

/// Fig. 2c: ratio of critical-path time to GPU active time (inference,
/// batch 1). Paper: latency could drop up to 3× with full parallelism,
/// i.e. ratios down to ~1/3.
pub fn fig2c() -> Table {
    let dev = GpuSpec::v100();
    let mut t = Table::new(vec!["model", "critical/active", "max parallel speedup"]);
    for name in ["inception_v3", "nasnet_a_mobile", "amoebanet", "darts"] {
        let g = models::build(name, 1);
        let costs = crate::baselines::baseline_costs(&g, Baseline::PyTorch, &dev);
        let cp = critical_path_s(&g, &costs);
        let active = total_kernel_s(&costs);
        t.row(vec![
            name.to_string(),
            format!("{:.2}", cp / active),
            fmt_x(active / cp),
        ]);
    }
    t
}

/// Fig. 7: relative inference speedup over PyTorch, batch 1, V100.
/// Paper anchors: Nimble up to 22.34× (NASNet-A mobile); ≥ TensorRT by up
/// to 2.81×; ≥ TVM by up to 1.70× except MobileNetV2.
pub fn fig7() -> Table {
    fig7_on(&GpuSpec::v100(), true)
}

fn fig7_on(dev: &GpuSpec, include_tvm: bool) -> Table {
    let mut header = vec!["model", "TorchScript", "Caffe2", "TensorRT"];
    if include_tvm {
        header.push("TVM");
    }
    header.extend(["Nimble", "paper Nimble"]);
    let mut t = Table::new(header);
    for name in FIG7_MODELS {
        let g = models::build(name, 1);
        let pt = simulate_inference(&g, Baseline::PyTorch, dev).total_s;
        let speedup = |b: Baseline| fmt_x(pt / simulate_inference(&g, b, dev).total_s);
        let mut row = vec![
            name.to_string(),
            speedup(Baseline::TorchScript),
            speedup(Baseline::Caffe2),
            speedup(Baseline::TensorRT),
        ];
        if include_tvm {
            row.push(speedup(Baseline::Tvm));
        }
        row.push(speedup(Baseline::Nimble));
        row.push(match *name {
            "nasnet_a_mobile" => "22.34x".to_string(),
            _ => "—".to_string(),
        });
        t.row(row);
    }
    t
}

/// Table 1: multi-stream vs single-stream Nimble + degree of logical
/// concurrency + #MACs.
pub fn table1() -> Table {
    let dev = GpuSpec::v100();
    let mut t = Table::new(vec![
        "architecture",
        "speedup",
        "paper speedup",
        "Deg.",
        "paper Deg.",
        "#MACs",
        "paper #MACs",
    ]);
    let rows: [(&str, f64, usize, &str); 5] = [
        ("inception_v3", 1.09, 6, "5.7B"),
        ("darts", 1.37, 7, "0.5B"),
        ("amoebanet", 1.45, 11, "0.5B"),
        ("nasnet_a_mobile", 1.88, 12, "0.6B"),
        ("nasnet_a_large", 1.31, 15, "23.9B"),
    ];
    for (name, paper_speedup, paper_deg, paper_macs) in rows {
        let g = models::build(name, 1);
        let single = simulate_inference(&g, Baseline::NimbleSingleStream, &dev).total_s;
        let multi = simulate_inference(&g, Baseline::Nimble, &dev).total_s;
        let deg = logical_concurrency_degree(&g);
        let macs = total_macs(&g) as f64 / 1e9;
        t.row(vec![
            name.to_string(),
            fmt_x(single / multi),
            fmt_x(paper_speedup),
            deg.to_string(),
            paper_deg.to_string(),
            format!("{macs:.1}B"),
            paper_macs.to_string(),
        ]);
    }
    t
}

/// Fig. 8: relative training-step speedup over PyTorch, batch 32.
/// Paper: up to 3.61× on CIFAR-scale inputs; marginal on ImageNet/BERT.
pub fn fig8() -> Table {
    fig8_at_batch(32)
}

fn fig8_at_batch(batch: usize) -> Table {
    let dev = GpuSpec::v100();
    let mut t = Table::new(vec!["model", "TorchScript", "Nimble", "paper note"]);
    let models_8 = [
        ("resnet50", "ImageNet: marginal (large kernels)"),
        ("bert_base", "seq 128: marginal (large matmuls)"),
        ("resnet50_cifar", "CIFAR-10: paper up to 3.61x"),
        ("mobilenet_v2_cifar", "CIFAR-10"),
        ("efficientnet_b0_cifar", "CIFAR-10"),
    ];
    for (name, note) in models_8 {
        let g = models::build_train(name, batch);
        let pt = simulate_training(&g, Baseline::PyTorch, &dev).total_s;
        let ts = simulate_training(&g, Baseline::TorchScript, &dev).total_s;
        let nb = simulate_training(&g, Baseline::Nimble, &dev).total_s;
        t.row(vec![name.to_string(), fmt_x(pt / ts), fmt_x(pt / nb), note.to_string()]);
    }
    t
}

/// Fig. 9: the Fig. 7 sweep on Titan RTX and Titan Xp (no TVM — the paper
/// excludes it since kernels would need re-tuning per GPU).
pub fn fig9() -> Vec<(String, Table)> {
    [GpuSpec::titan_rtx(), GpuSpec::titan_xp()]
        .into_iter()
        .map(|dev| (format!("fig9_{}", dev.name.to_lowercase()), fig7_on(&dev, false)))
        .collect()
}

/// Fig. 10: training speedup across batch sizes on the CIFAR-10 workloads.
pub fn fig10() -> Table {
    let dev = GpuSpec::v100();
    let batches = [32usize, 64, 128, 256];
    let mut header = vec!["model".to_string()];
    header.extend(batches.iter().map(|b| format!("b{b}")));
    let mut t = Table::new(header);
    for name in ["resnet50_cifar", "mobilenet_v2_cifar", "efficientnet_b0_cifar"] {
        let mut row = vec![name.to_string()];
        for &b in &batches {
            let g = models::build_train(name, b);
            let pt = simulate_training(&g, Baseline::PyTorch, &dev).total_s;
            let nb = simulate_training(&g, Baseline::Nimble, &dev).total_s;
            row.push(fmt_x(pt / nb));
        }
        t.row(row);
    }
    t
}

/// Run figures by name ("all" or a specific id), returning (name, table)
/// pairs and writing `results/<name>.tsv`.
pub fn run(which: &str, results_dir: &Path) -> anyhow::Result<Vec<(String, Table)>> {
    let mut out: Vec<(String, Table)> = Vec::new();
    let all = which == "all";
    if all || which == "fig2a" {
        out.push(("fig2a".into(), fig2a()));
    }
    if all || which == "fig2b" {
        out.push(("fig2b".into(), fig2b()));
    }
    if all || which == "fig2c" {
        out.push(("fig2c".into(), fig2c()));
    }
    if all || which == "fig7" {
        out.push(("fig7".into(), fig7()));
    }
    if all || which == "table1" {
        out.push(("table1".into(), table1()));
    }
    if all || which == "fig8" {
        out.push(("fig8".into(), fig8()));
    }
    if all || which == "fig9" {
        out.extend(fig9());
    }
    if all || which == "fig10" {
        out.push(("fig10".into(), fig10()));
    }
    anyhow::ensure!(!out.is_empty(), "unknown figure `{which}` (try: all, fig2a, fig2b, fig2c, fig7, table1, fig8, fig9, fig10)");
    for (name, table) in &out {
        table.write_tsv(&results_dir.join(format!("{name}.tsv")))?;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2b_reproduces_the_gap_direction() {
        let t = fig2b();
        assert_eq!(t.n_rows(), 2);
    }

    #[test]
    fn table1_has_all_architectures() {
        let t = table1();
        assert_eq!(t.n_rows(), 5);
    }

    #[test]
    fn fig9_covers_both_gpus() {
        let figs = fig9();
        assert_eq!(figs.len(), 2);
        assert!(figs[0].0.contains("titanrtx"));
    }

    #[test]
    fn run_writes_tsv() {
        let dir = std::env::temp_dir().join("nimble_fig_test");
        let out = run("fig2c", &dir).unwrap();
        assert_eq!(out.len(), 1);
        assert!(dir.join("fig2c.tsv").exists());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn run_rejects_unknown() {
        let dir = std::env::temp_dir();
        assert!(run("fig99", &dir).is_err());
    }
}
