//! The Nimble engine coordinator: ties the pipeline together.
//!
//! `NimbleEngine::build` runs the full Figure-4 flow once: load artifacts →
//! per batch size, build the operator DAG, run the Graph Rewriter
//! (Algorithm 1 + sync plan) and the AoT scheduler (pre-run interception,
//! memory reservation) → keep the task schedules for request-time replay.
//! An eager engine over the same executables serves as the run-time-
//! scheduling baseline (`ExecMode::Eager`).

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use crate::aot::TaskSchedule;
use crate::engine::EagerEngine;
use crate::runtime::{ArtifactRegistry, RuntimeClient};

/// Which execution path serves requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// AoT task-schedule replay (the paper's system).
    #[default]
    Replay,
    /// Run-time scheduling on every request (the baseline).
    Eager,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub artifacts_dir: PathBuf,
    pub mode: ExecMode,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { artifacts_dir: crate::runtime::artifacts_dir(), mode: ExecMode::Replay }
    }
}

/// A built engine: one task schedule + one eager engine per batch size.
pub struct NimbleEngine {
    pub registry: Arc<ArtifactRegistry>,
    pub config: EngineConfig,
    schedules: HashMap<usize, TaskSchedule>,
    eager: HashMap<usize, EagerEngine>,
}

impl NimbleEngine {
    /// Build the engine (compiles artifacts, runs AoT scheduling + pre-run
    /// for every batch size in the manifest).
    pub fn build(config: EngineConfig) -> Result<Self> {
        let client = RuntimeClient::cpu()?;
        let registry =
            Arc::new(ArtifactRegistry::load(client, config.artifacts_dir.clone())?);
        let mut schedules = HashMap::new();
        let mut eager = HashMap::new();
        for batch in registry.manifest.batch_sizes() {
            schedules.insert(batch, TaskSchedule::build(&registry, batch)?);
            eager.insert(batch, EagerEngine::new(registry.clone(), batch)?);
        }
        Ok(NimbleEngine { registry, config, schedules, eager })
    }

    /// Batch sizes this engine can serve.
    pub fn batch_sizes(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self.schedules.keys().copied().collect();
        b.sort_unstable();
        b
    }

    /// Largest supported batch.
    pub fn max_batch(&self) -> usize {
        self.batch_sizes().into_iter().max().unwrap_or(1)
    }

    pub fn schedule(&self, batch: usize) -> Result<&TaskSchedule> {
        self.schedules.get(&batch).with_context(|| format!("no schedule for batch {batch}"))
    }

    /// Per-example input length.
    pub fn example_len(&self, batch: usize) -> Result<usize> {
        let s = self.schedule(batch)?;
        Ok(s.input_dims.iter().product::<usize>() / batch)
    }

    /// Run one batch through the configured path.
    pub fn infer(&self, batch: usize, input: &[f32]) -> Result<Vec<f32>> {
        match self.config.mode {
            ExecMode::Replay => self.schedule(batch)?.replay(&self.registry, input),
            ExecMode::Eager => {
                let engine = self
                    .eager
                    .get(&batch)
                    .with_context(|| format!("no eager engine for batch {batch}"))?;
                Ok(engine.infer(input)?.0)
            }
        }
    }

    /// Run one batch through an explicit path (for A/B measurements).
    pub fn infer_mode(&self, mode: ExecMode, batch: usize, input: &[f32]) -> Result<Vec<f32>> {
        match mode {
            ExecMode::Replay => self.schedule(batch)?.replay(&self.registry, input),
            ExecMode::Eager => Ok(self.eager[&batch].infer(input)?.0),
        }
    }
}
