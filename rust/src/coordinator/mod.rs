//! The Nimble engine coordinator: ties the pipeline together.
//!
//! The ungated half defines the serving-facing [`InferEngine`] contract
//! (implemented by the PJRT-backed [`NimbleEngine`] and by the
//! virtual-substrate [`TapeEngine`](crate::serving::sim_engine::TapeEngine))
//! plus the engine configuration types.
//!
//! With the `xla` feature, `NimbleEngine::build` runs the full Figure-4
//! flow once: load artifacts → per batch size, build the operator DAG,
//! run the Graph Rewriter (Algorithm 1 + sync plan) and the AoT
//! scheduler (pre-run interception, memory reservation) → keep the task
//! schedules *and a reusable [`PreparedReplay`] context per batch
//! bucket* for request-time replay with no per-request slot-table or
//! argument-vector allocation. An eager engine over the same
//! executables serves as the run-time-scheduling baseline
//! (`ExecMode::Eager`).

use std::path::PathBuf;

#[cfg(feature = "xla")]
use anyhow::{Context, Result};
#[cfg(feature = "xla")]
use std::collections::HashMap;
#[cfg(feature = "xla")]
use std::sync::Arc;

#[cfg(feature = "xla")]
use crate::aot::{PreparedReplay, TaskSchedule};
#[cfg(feature = "xla")]
use crate::engine::EagerEngine;
#[cfg(feature = "xla")]
use crate::runtime::{ArtifactRegistry, RuntimeClient};

/// Which execution path serves requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// AoT task-schedule replay (the paper's system).
    #[default]
    Replay,
    /// Run-time scheduling on every request (the baseline).
    Eager,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub artifacts_dir: PathBuf,
    pub mode: ExecMode,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { artifacts_dir: crate::runtime::artifacts_dir(), mode: ExecMode::Replay }
    }
}

/// The serving contract: what the batched server needs from an engine.
/// Implementations are built *on* the engine thread (PJRT state is not
/// `Send`) and are driven mutably so they can keep reusable per-bucket
/// replay contexts.
pub trait InferEngine {
    /// Compiled batch-size buckets, ascending.
    fn batch_sizes(&self) -> Vec<usize>;
    /// Flattened input length of ONE example.
    fn example_len(&self) -> usize;
    /// Flattened output length of ONE example.
    fn output_len(&self) -> usize;
    /// Run one padded batch of `bucket` examples; returns the flattened
    /// outputs of all `bucket` examples (padding included).
    fn infer_batch(&mut self, bucket: usize, input: &[f32]) -> anyhow::Result<Vec<f32>>;

    /// Stream count of a bucket's replay context, when known — surfaced
    /// in the lane scheduler's per-lane stats
    /// ([`LaneStat`](crate::serving::metrics::LaneStat)).
    fn stream_count(&self, _bucket: usize) -> Option<usize> {
        None
    }

    /// Reserved arena bytes of a bucket's replay context, when known —
    /// the packed footprint from the stream-aware memory plan
    /// ([`crate::aot::memory`]), surfaced in the lane scheduler's
    /// per-lane stats.
    fn reserved_bytes(&self, _bucket: usize) -> Option<u64> {
        None
    }

    /// Cross-context worker steals this engine's replay contexts have
    /// received from a shared work-stealing pool
    /// ([`SharedWorkerPool`](crate::engine::executor::SharedWorkerPool)),
    /// when known — surfaced in the lane scheduler's per-lane stats
    /// (`LaneStat::steals`). `None` when the engine does not lease from
    /// a shared pool.
    fn steals(&self) -> Option<u64> {
        None
    }
}

/// A built engine: one task schedule + prepared replay context + eager
/// engine per batch size.
#[cfg(feature = "xla")]
pub struct NimbleEngine {
    pub registry: Arc<ArtifactRegistry>,
    pub config: EngineConfig,
    schedules: HashMap<usize, TaskSchedule>,
    prepared: HashMap<usize, PreparedReplay>,
    eager: HashMap<usize, EagerEngine>,
}

#[cfg(feature = "xla")]
impl NimbleEngine {
    /// Build the engine (compiles artifacts, runs AoT scheduling + pre-run
    /// for every batch size in the manifest).
    pub fn build(config: EngineConfig) -> Result<Self> {
        Self::build_subset(config, None)
    }

    /// Build an engine restricted to `buckets` — the per-lane constructor
    /// of the lane scheduler, where each lane thread owns an engine for
    /// exactly one batch bucket (so lanes never contend on shared PJRT
    /// state and a hot bucket cannot evict a cold one).
    pub fn build_for(config: EngineConfig, buckets: &[usize]) -> Result<Self> {
        Self::build_subset(config, Some(buckets))
    }

    fn build_subset(config: EngineConfig, buckets: Option<&[usize]>) -> Result<Self> {
        let client = RuntimeClient::cpu()?;
        let registry =
            Arc::new(ArtifactRegistry::load(client, config.artifacts_dir.clone())?);
        let available = registry.manifest.batch_sizes();
        let wanted: Vec<usize> = match buckets {
            Some(b) => {
                for &batch in b {
                    anyhow::ensure!(
                        available.contains(&batch),
                        "batch bucket {batch} not in the manifest (available: {available:?})"
                    );
                }
                b.to_vec()
            }
            None => available,
        };
        let mut schedules = HashMap::new();
        let mut prepared = HashMap::new();
        let mut eager = HashMap::new();
        for batch in wanted {
            let schedule = TaskSchedule::build(&registry, batch)?;
            prepared.insert(batch, schedule.prepare_replay());
            schedules.insert(batch, schedule);
            eager.insert(batch, EagerEngine::new(registry.clone(), batch)?);
        }
        Ok(NimbleEngine { registry, config, schedules, prepared, eager })
    }

    /// Batch sizes this engine can serve.
    pub fn batch_sizes(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self.schedules.keys().copied().collect();
        b.sort_unstable();
        b
    }

    /// Largest supported batch.
    pub fn max_batch(&self) -> usize {
        self.batch_sizes().into_iter().max().unwrap_or(1)
    }

    pub fn schedule(&self, batch: usize) -> Result<&TaskSchedule> {
        self.schedules.get(&batch).with_context(|| format!("no schedule for batch {batch}"))
    }

    /// Per-example input length.
    pub fn example_len(&self, batch: usize) -> Result<usize> {
        let s = self.schedule(batch)?;
        Ok(s.input_dims.iter().product::<usize>() / batch)
    }

    /// Run one batch through the configured path (unprepared replay;
    /// kept for A/B measurements against [`infer_prepared`](Self::infer_prepared)).
    pub fn infer(&self, batch: usize, input: &[f32]) -> Result<Vec<f32>> {
        match self.config.mode {
            ExecMode::Replay => self.schedule(batch)?.replay(&self.registry, input),
            ExecMode::Eager => {
                let engine = self
                    .eager
                    .get(&batch)
                    .with_context(|| format!("no eager engine for batch {batch}"))?;
                Ok(engine.infer(input)?.0)
            }
        }
    }

    /// Replay through the batch bucket's reusable [`PreparedReplay`]
    /// context — the serving hot path.
    pub fn infer_prepared(&mut self, batch: usize, input: &[f32]) -> Result<Vec<f32>> {
        let schedule =
            self.schedules.get(&batch).with_context(|| format!("no schedule for batch {batch}"))?;
        let prep = self
            .prepared
            .get_mut(&batch)
            .with_context(|| format!("no prepared context for batch {batch}"))?;
        schedule.replay_prepared(&self.registry, prep, input).map(|(out, _)| out)
    }

    /// Run one batch through an explicit path (for A/B measurements).
    pub fn infer_mode(&self, mode: ExecMode, batch: usize, input: &[f32]) -> Result<Vec<f32>> {
        match mode {
            ExecMode::Replay => self.schedule(batch)?.replay(&self.registry, input),
            ExecMode::Eager => Ok(self.eager[&batch].infer(input)?.0),
        }
    }
}

#[cfg(feature = "xla")]
impl InferEngine for NimbleEngine {
    fn batch_sizes(&self) -> Vec<usize> {
        NimbleEngine::batch_sizes(self)
    }

    fn example_len(&self) -> usize {
        NimbleEngine::example_len(self, self.max_batch()).expect("validated at build")
    }

    fn output_len(&self) -> usize {
        let batch = self.max_batch();
        let s = self.schedule(batch).expect("validated at build");
        s.output_dims.iter().product::<usize>() / batch
    }

    fn infer_batch(&mut self, bucket: usize, input: &[f32]) -> anyhow::Result<Vec<f32>> {
        match self.config.mode {
            ExecMode::Replay => self.infer_prepared(bucket, input),
            ExecMode::Eager => self.infer(bucket, input),
        }
    }
}
