//! Cluster layer: data-parallel device replica groups above
//! [`Runtime`] — ROADMAP item 1's multi-device serving tier.
//!
//! A [`Cluster`] owns N **replicas** of one model spec. Each replica
//! is a full [`Runtime`] with its *own*
//! [`SharedWorkerPool`](crate::engine::executor::SharedWorkerPool)
//! (its device), its own [`ArenaPool`] (its memory), its own lane group and
//! optional [`Telemetry`] — replicas share nothing at run time, which
//! is what makes them independently drainable and killable. In front
//! of them sits a deadline-aware **router**:
//!
//! - Requests whose deadline already expired are shed *at the door*,
//!   before routing (resolved [`InferOutcome::DeadlineShed`], counted
//!   in `ClusterReport::router_shed`).
//! - Everything else routes by **power-of-two-choices** on per-replica
//!   pressure — in-flight requests (staged + queued + executing) and
//!   the EWMA of observed queue delay — or by round-robin
//!   ([`ClusterBuilder::route_round_robin`], the bench baseline).
//!   Bucket hints and deadlines travel with the request; each
//!   replica's own EDF batcher and admission estimator still apply.
//! - The whole decision procedure is mirrored exactly by
//!   [`crate::sim::simulate_cluster`], so routing policies are judged
//!   offline with the same measured-vs-predicted discipline as the
//!   lane/chaos/EDF sims (`benches/bench_cluster.rs` pins a seeded
//!   closed-loop run to the sim bit-for-bit).
//!
//! **Lifecycle.** [`Cluster::drain_replica`] flips a replica out of
//! the routable set, then flushes everything it had admitted
//! ([`Runtime::drain`] semantics) — its in-flight tickets resolve
//! normally and *new* traffic reroutes to the survivors.
//! [`Cluster::kill_replica`] is the ungraceful variant used with
//! per-replica fault plans ([`ClusterBuilder::fault_plan`] derives a
//! distinct stream per replica via [`FaultPlan::derive_replica`]): a
//! failed replica's dead-lettered requests resolve as
//! [`InferOutcome::Failed`], and the cluster ticket **fails over** —
//! re-admitting the saved request on a surviving replica (counted in
//! `ClusterReport::failovers`). Tickets never dangle: every
//! [`ClusterTicket`] resolves exactly once no matter how replicas die.
//!
//! **SLO coupling.** [`ClusterBuilder::slo`] sets the same target shed
//! rate on every replica's lane controller (which force-spawns lanes
//! first) AND arms a cluster-level controller: when the cluster-wide
//! shed rate stays above target for two consecutive observation
//! windows — i.e. per-replica lane scaling has saturated — a new
//! replica is built from the shared spec and joins the routable set,
//! up to [`ClusterBuilder::max_replicas`].
//!
//! **Accounting.** With `submitted` the accepted submissions,
//! `router_shed` the door sheds and `failovers` the re-admissions:
//! `Σ admitted_r == submitted − router_shed + failovers`, every
//! replica's own `admitted == n_requests + deadline_shed + failed`
//! invariant still holds, and client-side outcomes satisfy
//! `completed + shed + failed == submitted`. The prop harness
//! (`tests/prop_harness.rs`) closes all three under drain/kill churn.

mod router;

pub use router::RoutePolicy;

use anyhow::{Context, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::aot::memory::ArenaPool;
use crate::aot::verify::VerifyMode;
use crate::fault::{FaultPlan, RetryPolicy};
use crate::ops::OpGraph;
use crate::serving::runtime::shed_error;
use crate::serving::{
    InferOutcome, InferRequest, LaneConfig, Runtime, RuntimeHandle, ScaleOptions,
    ServingReport, Ticket,
};
use crate::telemetry::Telemetry;
use router::RouterState;

/// EWMA smoothing for the per-replica queue-delay signal (same α as
/// the lane dispatcher's admission estimator).
const EWMA_ALPHA: f64 = 0.3;
/// Outcomes per SLO observation window of the replica-scaling
/// controller.
const SLO_WINDOW: u64 = 32;
/// Consecutive breached windows before a replica is spawned — one
/// window of grace for the lane-level controller to catch up first.
const SLO_BREACHES_TO_SCALE: u32 = 2;

/// What the replicas serve: a model-zoo name or an arbitrary
/// per-bucket graph builder (mirrors `RuntimeBuilder`'s sources; the
/// spec is shared, each replica builds its own engines from it).
enum ClusterSource {
    Model(String),
    Graph(Arc<dyn Fn(usize) -> OpGraph + Send + Sync>),
}

/// Where a replica is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaState {
    /// In the routable set.
    Live,
    /// [`Cluster::drain_replica`] in progress: out of the routable
    /// set, flushing everything already admitted.
    Draining,
    /// Drained cleanly; its final report is folded into the cluster's.
    Retired,
    /// [`Cluster::kill_replica`]ed; dead-lettered work failed over.
    Failed,
}

/// Hot per-replica counters, shared between the slot and every
/// [`ClusterTicket`] routed to it (tickets update them lock-free at
/// resolution).
struct ReplicaStats {
    /// Unresolved tickets routed here: staged + queued + executing.
    in_flight: AtomicUsize,
    /// Requests ever admitted here (routing signature; the exact bench
    /// pins it against the DES).
    admitted: AtomicU64,
    /// EWMA of observed submit→resolve delay, nanoseconds (0 = cold).
    /// Advisory: plain load/store, last writer wins.
    ewma_ns: AtomicU64,
}

impl ReplicaStats {
    fn new() -> Arc<ReplicaStats> {
        Arc::new(ReplicaStats {
            in_flight: AtomicUsize::new(0),
            admitted: AtomicU64::new(0),
            ewma_ns: AtomicU64::new(0),
        })
    }

    fn note_resolved(&self, elapsed: Duration) {
        self.in_flight.fetch_sub(1, Ordering::AcqRel);
        let sample = elapsed.as_nanos().min(u64::MAX as u128) as u64;
        let old = self.ewma_ns.load(Ordering::Relaxed);
        let next = if old == 0 {
            sample
        } else {
            (EWMA_ALPHA * sample as f64 + (1.0 - EWMA_ALPHA) * old as f64) as u64
        };
        self.ewma_ns.store(next.max(1), Ordering::Relaxed);
    }
}

/// One device replica: its runtime (taken on drain/kill), the labeled
/// handle used for routing and metrics, and the pools it exclusively
/// owns.
struct ReplicaSlot {
    runtime: Option<Runtime>,
    handle: RuntimeHandle,
    arena_pool: ArenaPool,
    telemetry: Option<Telemetry>,
    state: ReplicaState,
    stats: Arc<ReplicaStats>,
    /// Final report, stored when the replica leaves the routable set.
    report: Option<ServingReport>,
}

/// Everything shared between the cluster façade, its tickets, and the
/// scaling controller.
struct ClusterShared {
    spec: ClusterSpec,
    replicas: RwLock<Vec<ReplicaSlot>>,
    /// One decision mutex: routing decisions happen in submission
    /// order, the property the DES mirror depends on.
    router: Mutex<RouterState>,
    /// Serializes replica spawns (the scaling controller).
    scaling: Mutex<()>,
    submitted: AtomicU64,
    router_shed: AtomicU64,
    failovers: AtomicU64,
    replicas_spawned: AtomicU64,
    slo: Option<SloCtl>,
}

struct SloCtl {
    target: f64,
    window: Mutex<SloWindow>,
}

#[derive(Default)]
struct SloWindow {
    total: u64,
    shed: u64,
    breaches: u32,
}

/// The shared model spec every replica is built from.
struct ClusterSpec {
    label: String,
    source: ClusterSource,
    buckets: Vec<usize>,
    lane: LaneConfig,
    workers_per_replica: Option<usize>,
    worker_cap: Option<usize>,
    fault: Option<FaultPlan>,
    replica_faults: Vec<(usize, FaultPlan)>,
    telemetry: bool,
    verify: VerifyMode,
    max_replicas: usize,
    failover: usize,
    policy: RoutePolicy,
}

impl ClusterSpec {
    /// The fault plan replica `index` runs under: an explicit override
    /// ([`ClusterBuilder::replica_fault_plan`]) or the base plan's
    /// per-replica derivation — distinct decision streams per replica,
    /// reproducible across respawns.
    fn fault_for(&self, index: usize) -> Option<FaultPlan> {
        if let Some((_, plan)) = self.replica_faults.iter().find(|(i, _)| *i == index) {
            return Some(plan.clone());
        }
        self.fault.as_ref().map(|p| p.derive_replica(index))
    }

    /// Build replica `index`: its own arena pool, its own shared
    /// worker pool (when sized), its own recorder — nothing shared.
    fn build_replica(&self, index: usize) -> Result<ReplicaSlot> {
        let arena_pool = ArenaPool::new();
        let telemetry = self.telemetry.then(Telemetry::new);
        let mut lane = self.lane.clone();
        lane.telemetry = telemetry.clone();
        let b = match &self.source {
            ClusterSource::Model(name) => Runtime::builder().model(name),
            ClusterSource::Graph(f) => {
                let f = Arc::clone(f);
                Runtime::builder().graph_fn(move |bucket| (*f)(bucket))
            }
        };
        let mut b = b
            .label(&format!("{}/replica{index}", self.label))
            .buckets(&self.buckets)
            .lane_config(lane)
            .arena_pool(arena_pool.clone())
            .verify(self.verify);
        if let Some(workers) = self.workers_per_replica {
            b = b.shared_pool(workers);
        }
        if let Some(cap) = self.worker_cap {
            b = b.worker_cap(cap);
        }
        if let Some(plan) = self.fault_for(index) {
            b = b.fault_plan(plan);
        }
        let runtime = b.build().with_context(|| format!("building replica {index}"))?;
        let handle = runtime.handle().with_replica_label(index as u32);
        Ok(ReplicaSlot {
            runtime: Some(runtime),
            handle,
            arena_pool,
            telemetry,
            state: ReplicaState::Live,
            stats: ReplicaStats::new(),
            report: None,
        })
    }
}

impl ClusterShared {
    /// Record one client-visible outcome in the SLO window; two
    /// consecutive breached windows spawn a replica (the lane-level
    /// controller inside each replica has had a full window to act
    /// first — replica scale-out is the saturation escape hatch).
    fn note_outcome(self: &Arc<Self>, shed: bool) {
        let Some(ctl) = &self.slo else { return };
        let scale = {
            let mut w = ctl.window.lock().unwrap_or_else(|e| e.into_inner());
            w.total += 1;
            if shed {
                w.shed += 1;
            }
            if w.total < SLO_WINDOW {
                false
            } else {
                let rate = w.shed as f64 / w.total as f64;
                w.total = 0;
                w.shed = 0;
                if rate > ctl.target {
                    w.breaches += 1;
                } else {
                    w.breaches = 0;
                }
                if w.breaches >= SLO_BREACHES_TO_SCALE {
                    w.breaches = 0;
                    true
                } else {
                    false
                }
            }
        };
        if scale {
            self.try_scale_out();
        }
    }

    /// Spawn one replica from the spec if the cluster is still under
    /// its ceiling. Building happens outside the replicas lock;
    /// concurrent attempts are collapsed by the scaling mutex.
    fn try_scale_out(self: &Arc<Self>) {
        let Ok(_guard) = self.scaling.try_lock() else { return };
        let index = {
            let reps = self.replicas.read().unwrap_or_else(|e| e.into_inner());
            let live =
                reps.iter().filter(|r| r.state == ReplicaState::Live).count();
            if live >= self.spec.max_replicas {
                return;
            }
            reps.len()
        };
        if let Ok(slot) = self.spec.build_replica(index) {
            let mut reps = self.replicas.write().unwrap_or_else(|e| e.into_inner());
            reps.push(slot);
            self.replicas_spawned.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Route and admit one request, excluding `exclude` (the replica a
    /// failover just left). Returns the inner ticket plus the chosen
    /// replica's identity. Retries across remaining candidates when a
    /// replica refuses admission (drain races); propagates the error
    /// only when no candidate accepts.
    fn admit(
        &self,
        req: &InferRequest,
        exclude: Option<usize>,
    ) -> Result<(Ticket, usize, Arc<ReplicaStats>)> {
        let reps = self.replicas.read().unwrap_or_else(|e| e.into_inner());
        let mut routable: Vec<usize> = reps
            .iter()
            .enumerate()
            .filter(|(i, r)| r.state == ReplicaState::Live && Some(*i) != exclude)
            .map(|(i, _)| i)
            .collect();
        let mut last_err = anyhow::anyhow!("no live replicas to route to");
        while !routable.is_empty() {
            let chosen = {
                let mut router =
                    self.router.lock().unwrap_or_else(|e| e.into_inner());
                router.choose(&routable, |i| {
                    let slot = &reps[i];
                    let in_flight = slot.stats.in_flight.load(Ordering::Acquire);
                    let ewma_s =
                        slot.stats.ewma_ns.load(Ordering::Relaxed) as f64 * 1e-9;
                    (ewma_s * in_flight as f64, in_flight, i)
                })
            };
            let slot = &reps[chosen];
            match slot.handle.submit(req.clone()) {
                Ok(ticket) => {
                    slot.stats.admitted.fetch_add(1, Ordering::Relaxed);
                    slot.stats.in_flight.fetch_add(1, Ordering::AcqRel);
                    return Ok((ticket, chosen, Arc::clone(&slot.stats)));
                }
                Err(e) => {
                    // Validation errors fail on every replica alike —
                    // propagate them instead of spinning the router.
                    if crate::serving::is_validation_error(&e) {
                        return Err(e);
                    }
                    routable.retain(|&i| i != chosen);
                    last_err = e;
                }
            }
        }
        Err(last_err)
    }

    /// A ticket pre-resolved as [`InferOutcome::DeadlineShed`] — what
    /// the door shed hands back so every submission gets a real,
    /// waitable ticket.
    fn shed_ticket() -> Ticket {
        let (tx, rx) = mpsc::channel();
        let _ = tx.send(Err(shed_error()));
        Ticket::new(rx)
    }
}

/// Builds a [`Cluster`]: the shared model spec, the replica count, the
/// routing policy, and the per-replica knobs forwarded to each
/// replica's [`RuntimeBuilder`](crate::serving::RuntimeBuilder).
pub struct ClusterBuilder {
    label: String,
    source: Option<ClusterSource>,
    buckets: Vec<usize>,
    lane: LaneConfig,
    workers_per_replica: Option<usize>,
    worker_cap: Option<usize>,
    fault: Option<FaultPlan>,
    replica_faults: Vec<(usize, FaultPlan)>,
    telemetry: bool,
    verify: VerifyMode,
    replicas: usize,
    max_replicas: Option<usize>,
    failover: usize,
    policy: RoutePolicy,
    slo: Option<f64>,
}

impl Default for ClusterBuilder {
    fn default() -> Self {
        ClusterBuilder {
            label: "cluster".to_string(),
            source: None,
            buckets: vec![1, 8],
            lane: LaneConfig::default(),
            workers_per_replica: None,
            worker_cap: None,
            fault: None,
            replica_faults: Vec::new(),
            telemetry: false,
            verify: VerifyMode::default(),
            replicas: 2,
            max_replicas: None,
            failover: 1,
            policy: RoutePolicy::default(),
            slo: None,
        }
    }
}

impl ClusterBuilder {
    /// Serve a model-zoo network on every replica.
    pub fn model(mut self, name: &str) -> Self {
        self.label = name.to_string();
        self.source = Some(ClusterSource::Model(name.to_string()));
        self
    }

    /// Serve an arbitrary per-bucket operator-graph builder.
    pub fn graph_fn(
        mut self,
        build: impl Fn(usize) -> OpGraph + Send + Sync + 'static,
    ) -> Self {
        self.source = Some(ClusterSource::Graph(Arc::new(build)));
        self
    }

    /// Label prefix for replicas and error messages.
    pub fn label(mut self, label: &str) -> Self {
        self.label = label.to_string();
        self
    }

    /// Compiled batch-size buckets for every replica.
    pub fn buckets(mut self, buckets: &[usize]) -> Self {
        self.buckets = buckets.to_vec();
        self
    }

    /// Initial replica count (default 2).
    pub fn replicas(mut self, n: usize) -> Self {
        self.replicas = n;
        self
    }

    /// Replica ceiling the SLO controller may scale out to (default:
    /// the initial count — scale-out disabled).
    pub fn max_replicas(mut self, n: usize) -> Self {
        self.max_replicas = Some(n);
        self
    }

    /// Power-of-two-choices routing with this router seed (the
    /// default, seed 0). The seed is the knob that makes a closed-loop
    /// run reproducible by [`crate::sim::simulate_cluster`].
    pub fn route_p2c(mut self, seed: u64) -> Self {
        self.policy = RoutePolicy::P2c { seed };
        self
    }

    /// Round-robin routing (the baseline p2c is benched against).
    pub fn route_round_robin(mut self) -> Self {
        self.policy = RoutePolicy::RoundRobin;
        self
    }

    /// Dead-letter failover budget per request: how many times a
    /// request resolved [`InferOutcome::Failed`] is re-admitted on a
    /// surviving replica before the failure is surfaced (default 1;
    /// 0 disables failover).
    pub fn failover(mut self, attempts: usize) -> Self {
        self.failover = attempts;
        self
    }

    /// Replace each replica's whole lane configuration.
    pub fn lane_config(mut self, config: LaneConfig) -> Self {
        self.lane = config;
        self
    }

    /// Max partial-batch wait per replica ([`LaneConfig::max_wait`]).
    pub fn max_wait(mut self, max_wait: Duration) -> Self {
        self.lane.max_wait = max_wait;
        self
    }

    /// Per-lane job-queue capacity ([`LaneConfig::lane_cap`]).
    pub fn lane_cap(mut self, cap: usize) -> Self {
        self.lane.lane_cap = cap;
        self
    }

    /// Pooled padded-input buffers per lane
    /// ([`LaneConfig::buffers_per_lane`]).
    pub fn buffers_per_lane(mut self, n: usize) -> Self {
        self.lane.buffers_per_lane = n;
        self
    }

    /// Elastic lane scaling inside each replica.
    pub fn elastic(mut self, scale: ScaleOptions) -> Self {
        self.lane.scale = scale;
        self
    }

    /// Earliest-deadline-first batching per replica (default on).
    pub fn edf(mut self, on: bool) -> Self {
        self.lane.edf = on;
        self
    }

    /// Bounded retry of transiently-failed batches per replica.
    pub fn retry_policy(mut self, retry: RetryPolicy) -> Self {
        self.lane.retry = retry;
        self
    }

    /// Workers in each replica's own [`SharedWorkerPool`] (its device;
    /// replicas never share replay threads).
    pub fn workers_per_replica(mut self, n: usize) -> Self {
        self.workers_per_replica = Some(n);
        self
    }

    /// Per-context worker cap when no shared pool is sized.
    pub fn worker_cap(mut self, cap: usize) -> Self {
        self.worker_cap = Some(cap);
        self
    }

    /// Seeded chaos for the whole cluster: replica `i` runs under
    /// `plan.derive_replica(i)` — one seed, disjoint per-replica fault
    /// streams ([`FaultPlan::derive_replica`]).
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault = Some(plan);
        self
    }

    /// Pin replica `index` to an explicit fault plan (overrides the
    /// cluster-wide derivation — how tests make exactly one replica
    /// lethal).
    pub fn replica_fault_plan(mut self, index: usize, plan: FaultPlan) -> Self {
        self.replica_faults.retain(|(i, _)| *i != index);
        self.replica_faults.push((index, plan));
        self
    }

    /// Attach a flight recorder to every replica. Per-replica
    /// Prometheus expositions are labeled `replica="<i>"` and merged
    /// collision-free by [`Cluster::metrics_text`].
    pub fn telemetry(mut self) -> Self {
        self.telemetry = true;
        self
    }

    /// SLO target shed rate, coupled across BOTH controllers: each
    /// replica's lane controller (scales lanes first) and the cluster
    /// controller (scales replicas once lanes saturate, up to
    /// [`max_replicas`](Self::max_replicas)).
    pub fn slo(mut self, target_shed_rate: f64) -> Self {
        self.slo = Some(target_shed_rate);
        self
    }

    /// Static plan verification policy forwarded to every replica.
    pub fn verify(mut self, mode: VerifyMode) -> Self {
        self.verify = mode;
        self
    }

    /// Build the cluster: every replica from the one spec, each with
    /// its own pools.
    pub fn build(self) -> Result<Cluster> {
        anyhow::ensure!(self.replicas >= 1, "a cluster needs at least one replica");
        anyhow::ensure!(
            self.source.is_some(),
            "ClusterBuilder needs a source: model() or graph_fn()"
        );
        let max_replicas = self.max_replicas.unwrap_or(self.replicas);
        anyhow::ensure!(
            max_replicas >= self.replicas,
            "max_replicas {max_replicas} below the initial replica count {}",
            self.replicas
        );
        if let Some(target) = self.slo {
            anyhow::ensure!(
                (0.0..=1.0).contains(&target),
                "slo() target shed rate must be in [0, 1], got {target}"
            );
        }
        let mut lane = self.lane;
        lane.slo = self.slo;
        let spec = ClusterSpec {
            label: self.label,
            source: self.source.expect("checked above"),
            buckets: self.buckets,
            lane,
            workers_per_replica: self.workers_per_replica,
            worker_cap: self.worker_cap,
            fault: self.fault,
            replica_faults: self.replica_faults,
            telemetry: self.telemetry,
            verify: self.verify,
            max_replicas,
            failover: self.failover,
            policy: self.policy.clone(),
        };
        let slots: Vec<ReplicaSlot> = (0..self.replicas)
            .map(|i| spec.build_replica(i))
            .collect::<Result<_>>()?;
        let router = Mutex::new(RouterState::new(&spec.policy));
        let slo = self.slo.map(|target| SloCtl {
            target,
            window: Mutex::new(SloWindow::default()),
        });
        Ok(Cluster {
            shared: Arc::new(ClusterShared {
                spec,
                replicas: RwLock::new(slots),
                router,
                scaling: Mutex::new(()),
                submitted: AtomicU64::new(0),
                router_shed: AtomicU64::new(0),
                failovers: AtomicU64::new(0),
                replicas_spawned: AtomicU64::new(0),
                slo,
            }),
        })
    }
}

/// N device replicas behind one deadline-aware router — see the
/// [module docs](self).
pub struct Cluster {
    shared: Arc<ClusterShared>,
}

impl Cluster {
    pub fn builder() -> ClusterBuilder {
        ClusterBuilder::default()
    }

    /// Flattened input length of one example (identical on every
    /// replica — one spec).
    pub fn example_len(&self) -> usize {
        let reps = self.shared.replicas.read().unwrap_or_else(|e| e.into_inner());
        reps[0].handle.example_len()
    }

    /// Flattened output length of one example.
    pub fn output_len(&self) -> usize {
        let reps = self.shared.replicas.read().unwrap_or_else(|e| e.into_inner());
        reps[0].handle.output_len()
    }

    /// Compiled batch buckets, ascending.
    pub fn batch_sizes(&self) -> Vec<usize> {
        let reps = self.shared.replicas.read().unwrap_or_else(|e| e.into_inner());
        reps[0].handle.batch_sizes().to_vec()
    }

    /// Replicas currently in the routable set.
    pub fn live_replicas(&self) -> usize {
        let reps = self.shared.replicas.read().unwrap_or_else(|e| e.into_inner());
        reps.iter().filter(|r| r.state == ReplicaState::Live).count()
    }

    /// Lifecycle state of every replica slot, index order (retired
    /// slots keep their index — routing signatures stay stable).
    pub fn replica_states(&self) -> Vec<ReplicaState> {
        let reps = self.shared.replicas.read().unwrap_or_else(|e| e.into_inner());
        reps.iter().map(|r| r.state).collect()
    }

    /// Requests ever admitted per replica, index order — the routing
    /// signature [`crate::sim::simulate_cluster`] reproduces exactly
    /// for seeded closed-loop runs.
    pub fn admitted_per_replica(&self) -> Vec<u64> {
        let reps = self.shared.replicas.read().unwrap_or_else(|e| e.into_inner());
        reps.iter().map(|r| r.stats.admitted.load(Ordering::Relaxed)).collect()
    }

    /// Submit a request: door-shed if already expired, otherwise route
    /// to a live replica. The returned [`ClusterTicket`] resolves
    /// exactly once and fails over dead-lettered requests
    /// transparently.
    pub fn submit(&self, req: InferRequest) -> Result<ClusterTicket> {
        let shared = Arc::clone(&self.shared);
        // Door shed: expired before routing — no draw, no replica.
        if req.opts.deadline.is_some_and(|d| d <= Instant::now()) {
            shared.submitted.fetch_add(1, Ordering::Relaxed);
            shared.router_shed.fetch_add(1, Ordering::Relaxed);
            shared.note_outcome(true);
            return Ok(ClusterTicket {
                inner: Some(ClusterShared::shed_ticket()),
                route: None,
                saved: None,
                attempts: 0,
                submitted_at: Instant::now(),
                shared,
            });
        }
        let (ticket, replica, stats) = shared.admit(&req, None)?;
        // Count only accepted submissions: an errored admit (bad
        // input, nothing routable) must not skew the accounting
        // invariant `submitted == completed + shed + failed`.
        shared.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(ClusterTicket {
            inner: Some(ticket),
            route: Some((replica, stats)),
            saved: Some(req),
            attempts: self.shared.spec.failover,
            submitted_at: Instant::now(),
            shared,
        })
    }

    /// Blocking inference: submit and wait (sheds and failures become
    /// errors, as in [`Runtime::infer`]).
    pub fn infer(&self, req: InferRequest) -> Result<Vec<f32>> {
        match self.submit(req)?.outcome()? {
            InferOutcome::Output(v) => Ok(v),
            InferOutcome::DeadlineShed => Err(anyhow::anyhow!(shed_error())),
            InferOutcome::Failed(e) => Err(anyhow::anyhow!(e)),
        }
    }

    /// Gracefully drain replica `index`: leave the routable set first
    /// (new traffic reroutes), then flush everything it had admitted —
    /// staged batches, queued jobs, retries — so every in-flight
    /// ticket resolves. Returns the replica's final report (also kept
    /// for the cluster report).
    pub fn drain_replica(&self, index: usize) -> Result<ServingReport> {
        self.retire(index, ReplicaState::Draining, ReplicaState::Retired)
    }

    /// Kill replica `index`: identical mechanics to a drain (this
    /// substrate has no way to abandon threads safely), but marked
    /// [`ReplicaState::Failed`]. Under a per-replica fault plan the
    /// dead letters resolve as `Failed` and the cluster tickets fail
    /// over to survivors.
    pub fn kill_replica(&self, index: usize) -> Result<ServingReport> {
        self.retire(index, ReplicaState::Draining, ReplicaState::Failed)
    }

    fn retire(
        &self,
        index: usize,
        via: ReplicaState,
        end: ReplicaState,
    ) -> Result<ServingReport> {
        let runtime = {
            let mut reps =
                self.shared.replicas.write().unwrap_or_else(|e| e.into_inner());
            let n = reps.len();
            let slot = reps
                .get_mut(index)
                .with_context(|| format!("no replica {index} (have {n})"))?;
            anyhow::ensure!(
                slot.state == ReplicaState::Live,
                "replica {index} is {:?}, not Live",
                slot.state
            );
            slot.state = via;
            slot.runtime.take().expect("a Live replica owns its runtime")
        };
        let report = runtime.drain()?;
        let mut reps = self.shared.replicas.write().unwrap_or_else(|e| e.into_inner());
        reps[index].state = end;
        reps[index].report = Some(report.clone());
        Ok(report)
    }

    /// One Prometheus exposition for the whole cluster: every
    /// replica's samples (each labeled `replica="<i>"`), one
    /// `# HELP`/`# TYPE` header per family, samples grouped per family
    /// — no duplicate series, no duplicate metadata. `None` without
    /// [`ClusterBuilder::telemetry`].
    pub fn metrics_text(&self) -> Option<String> {
        let reps = self.shared.replicas.read().unwrap_or_else(|e| e.into_inner());
        let texts: Vec<String> =
            reps.iter().filter_map(|r| r.handle.metrics_text()).collect();
        if texts.is_empty() {
            return None;
        }
        Some(merge_expositions(&texts))
    }

    /// Stop the whole cluster: drain every live replica (flushing all
    /// admitted work), fold the per-replica reports, and return the
    /// [`ClusterReport`].
    pub fn shutdown(self) -> Result<ClusterReport> {
        let indices: Vec<usize> = {
            let reps =
                self.shared.replicas.read().unwrap_or_else(|e| e.into_inner());
            reps.iter()
                .enumerate()
                .filter(|(_, r)| r.state == ReplicaState::Live)
                .map(|(i, _)| i)
                .collect()
        };
        for i in indices {
            let _ = self.retire(i, ReplicaState::Draining, ReplicaState::Retired)?;
        }
        let reps = self.shared.replicas.read().unwrap_or_else(|e| e.into_inner());
        let mut total = ServingReport::empty();
        let mut per_replica = Vec::with_capacity(reps.len());
        let mut leased_arena_bytes = 0u64;
        for (i, slot) in reps.iter().enumerate() {
            if let Some(r) = &slot.report {
                total.absorb(r);
            }
            leased_arena_bytes += slot.arena_pool.stats().leased_bytes;
            per_replica.push(ReplicaReport {
                index: i,
                state: slot.state,
                admitted: slot.stats.admitted.load(Ordering::Relaxed),
                report: slot.report.clone(),
            });
        }
        Ok(ClusterReport {
            submitted: self.shared.submitted.load(Ordering::Relaxed),
            router_shed: self.shared.router_shed.load(Ordering::Relaxed),
            failovers: self.shared.failovers.load(Ordering::Relaxed),
            replicas_spawned: self.shared.replicas_spawned.load(Ordering::Relaxed),
            leased_arena_bytes,
            per_replica,
            total,
        })
    }
}

/// Waitable handle to a cluster submission. Wraps the routed replica's
/// [`Ticket`] and adds the cluster semantics: door-shed resolution,
/// per-replica accounting, and dead-letter failover. Resolves exactly
/// once; dropping an unresolved ticket releases its in-flight slot.
pub struct ClusterTicket {
    inner: Option<Ticket>,
    route: Option<(usize, Arc<ReplicaStats>)>,
    saved: Option<InferRequest>,
    attempts: usize,
    submitted_at: Instant,
    shared: Arc<ClusterShared>,
}

impl ClusterTicket {
    /// The replica currently serving this request (`None` for
    /// door-shed tickets).
    pub fn replica(&self) -> Option<usize> {
        self.route.as_ref().map(|(i, _)| *i)
    }

    /// Block for the outcome. `Failed` outcomes with failover budget
    /// left are re-admitted on a surviving replica (excluding the one
    /// that failed); the caller sees only the final resolution.
    pub fn outcome(mut self) -> Result<InferOutcome> {
        loop {
            let out = self
                .inner
                .take()
                .expect("an unresolved ticket owns its channel")
                .outcome()?;
            if let Some((_, stats)) = &self.route {
                stats.note_resolved(self.submitted_at.elapsed());
            }
            let failed_on = self.route.take().map(|(i, _)| i);
            match out {
                InferOutcome::Failed(_) if self.attempts > 0 && self.saved.is_some() => {
                    self.attempts -= 1;
                    let req = self.saved.clone().expect("checked");
                    match self.shared.admit(&req, failed_on) {
                        Ok((ticket, replica, stats)) => {
                            self.shared.failovers.fetch_add(1, Ordering::Relaxed);
                            self.inner = Some(ticket);
                            self.route = Some((replica, stats));
                            self.submitted_at = Instant::now();
                            continue;
                        }
                        // No surviving replica takes it: surface the
                        // original failure.
                        Err(_) => {
                            self.shared.note_outcome(false);
                            return Ok(out);
                        }
                    }
                }
                out => {
                    // Door sheds were already counted in the SLO
                    // window at submit time (`saved` is only `None`
                    // for door-shed tickets).
                    if self.saved.is_some() {
                        self.shared.note_outcome(out.is_shed());
                    }
                    return Ok(out);
                }
            }
        }
    }

    /// Like [`outcome`](Self::outcome) with a per-attempt wait bound;
    /// `Err` only on timeout or if the ticket already resolved. A
    /// timeout does NOT abandon the request: the inner ticket and its
    /// in-flight slot stay held (the replica is still executing it),
    /// so call again to keep waiting — or drop the `ClusterTicket`,
    /// which releases the slot only because the wait was abandoned.
    pub fn outcome_timeout(&mut self, timeout: Duration) -> Result<InferOutcome> {
        loop {
            let Some(out) = self
                .inner
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("ticket already resolved"))?
                .poll_timeout(timeout)
            else {
                // Timed out: leave `inner` and `route` untouched so
                // the pressure signal keeps counting the
                // still-executing request and a re-wait can pick the
                // outcome up.
                return Err(anyhow::anyhow!("timed out waiting for the request outcome"));
            };
            self.inner = None;
            if let Some((_, stats)) = &self.route {
                stats.note_resolved(self.submitted_at.elapsed());
            }
            let failed_on = self.route.take().map(|(i, _)| i);
            match out {
                InferOutcome::Failed(_) if self.attempts > 0 && self.saved.is_some() => {
                    self.attempts -= 1;
                    let req = self.saved.clone().expect("checked");
                    match self.shared.admit(&req, failed_on) {
                        Ok((ticket, replica, stats)) => {
                            self.shared.failovers.fetch_add(1, Ordering::Relaxed);
                            self.inner = Some(ticket);
                            self.route = Some((replica, stats));
                            self.submitted_at = Instant::now();
                            continue;
                        }
                        Err(_) => {
                            self.shared.note_outcome(false);
                            return Ok(out);
                        }
                    }
                }
                out => {
                    // Door sheds were already counted in the SLO
                    // window at submit time (`saved` is only `None`
                    // for door-shed tickets).
                    if self.saved.is_some() {
                        self.shared.note_outcome(out.is_shed());
                    }
                    return Ok(out);
                }
            }
        }
    }

    /// Block for the output; sheds and failures become errors.
    pub fn wait(self) -> Result<Vec<f32>> {
        match self.outcome()? {
            InferOutcome::Output(v) => Ok(v),
            InferOutcome::DeadlineShed => Err(anyhow::anyhow!(shed_error())),
            InferOutcome::Failed(e) => Err(anyhow::anyhow!(e)),
        }
    }
}

impl Drop for ClusterTicket {
    fn drop(&mut self) {
        // An unresolved, still-routed ticket (dropped without waiting)
        // releases its in-flight slot so the pressure signal recovers.
        if let Some((_, stats)) = self.route.take() {
            stats.in_flight.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

/// Per-replica slice of a [`ClusterReport`].
#[derive(Debug, Clone)]
pub struct ReplicaReport {
    pub index: usize,
    pub state: ReplicaState,
    /// Requests the router admitted here (including failover
    /// re-admissions).
    pub admitted: u64,
    /// The replica's final serving report (`None` only if it never
    /// left the routable set — impossible after
    /// [`Cluster::shutdown`]).
    pub report: Option<ServingReport>,
}

/// Aggregated report of a whole cluster run ([`Cluster::shutdown`]).
#[derive(Debug, Clone)]
pub struct ClusterReport {
    /// Accepted [`Cluster::submit`] calls.
    pub submitted: u64,
    /// Requests shed at the router's door, before any replica.
    pub router_shed: u64,
    /// Dead-letter re-admissions performed by tickets.
    pub failovers: u64,
    /// Replicas spawned by the SLO controller.
    pub replicas_spawned: u64,
    /// Arena bytes still leased across every replica's pool after the
    /// final drain — 0 iff all batch buffers came home.
    pub leased_arena_bytes: u64,
    pub per_replica: Vec<ReplicaReport>,
    /// Every replica's report folded with [`ServingReport::absorb`].
    pub total: ServingReport,
}

impl ClusterReport {
    /// Requests completed across the cluster.
    pub fn completed(&self) -> usize {
        self.total.n_requests
    }

    /// All sheds: door sheds plus every replica's deadline sheds — the
    /// counterpart of [`ClusterSimResult::shed`](crate::sim::ClusterSimResult::shed).
    pub fn shed(&self) -> usize {
        self.router_shed as usize + self.total.deadline_shed
    }

    /// Requests that resolved `Failed` inside replicas (failover
    /// re-admissions that later succeeded are NOT failures to the
    /// client, but each failed attempt is counted here by the replica
    /// that dead-lettered it).
    pub fn failed(&self) -> usize {
        self.total.failed
    }

    /// Per-replica admitted counts, index order.
    pub fn admitted_per_replica(&self) -> Vec<u64> {
        self.per_replica.iter().map(|r| r.admitted).collect()
    }

    /// The cluster-level conservation law:
    /// `Σ admitted == submitted − router_shed + failovers` and every
    /// admitted request resolved exactly once inside its replica
    /// (`Σ (n_requests + deadline_shed + failed) == Σ admitted`).
    pub fn accounting_closes(&self) -> bool {
        let admitted: u64 = self.per_replica.iter().map(|r| r.admitted).sum();
        let resolved =
            (self.total.n_requests + self.total.deadline_shed + self.total.failed) as u64;
        admitted == self.submitted - self.router_shed + self.failovers
            && resolved == admitted
    }

    /// Machine-readable counterpart of [`render`](Self::render) —
    /// parseable by [`crate::util::json::parse_json`].
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let mut o = String::from("{\n");
        let _ = write!(
            o,
            "  \"submitted\": {}, \"router_shed\": {}, \"failovers\": {}, \
             \"replicas_spawned\": {}, \"accounting_closes\": {},\n  \"admitted_per_replica\": [",
            self.submitted,
            self.router_shed,
            self.failovers,
            self.replicas_spawned,
            self.accounting_closes(),
        );
        for (i, r) in self.per_replica.iter().enumerate() {
            if i > 0 {
                o.push_str(", ");
            }
            let _ = write!(o, "{}", r.admitted);
        }
        o.push_str("],\n  \"total\": ");
        o.push_str(&self.total.to_json());
        o.push_str("}\n");
        o
    }

    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = format!(
            "cluster: submitted={} router_shed={} failovers={} spawned={}\n",
            self.submitted, self.router_shed, self.failovers, self.replicas_spawned
        );
        for r in &self.per_replica {
            let _ = write!(out, "replica[{}] {:?}: admitted={}", r.index, r.state, r.admitted);
            if let Some(rep) = &r.report {
                let _ = write!(
                    out,
                    " completed={} shed={} failed={}",
                    rep.n_requests, rep.deadline_shed, rep.failed
                );
            }
            out.push('\n');
        }
        out.push_str(&self.total.render());
        out
    }
}

/// Merge per-replica Prometheus expositions into one: a family's
/// `# HELP`/`# TYPE` metadata appears once, its samples (already
/// disambiguated by their `replica` labels) are grouped together in
/// first-seen family order.
pub(crate) fn merge_expositions(texts: &[String]) -> String {
    use std::collections::HashMap;
    // family name -> (metadata lines, sample lines). Only `# HELP` /
    // `# TYPE` open a family; samples seen before any header keep
    // their leading position, and other comment lines (e.g. `# EOF`)
    // are carried through at the end — nothing is silently dropped
    // if the exposition format changes.
    let mut order: Vec<String> = Vec::new();
    let mut fams: HashMap<String, (Vec<String>, Vec<String>)> = HashMap::new();
    let mut preamble: Vec<String> = Vec::new();
    let mut trailing: Vec<String> = Vec::new();
    for text in texts {
        let mut current: Option<String> = None;
        for line in text.lines() {
            if line.is_empty() {
                continue;
            }
            let family = line
                .strip_prefix("# HELP ")
                .or_else(|| line.strip_prefix("# TYPE "))
                .map(|rest| rest.split(' ').next().unwrap_or("").to_string());
            if let Some(name) = family {
                let entry = fams.entry(name.clone()).or_insert_with(|| {
                    order.push(name.clone());
                    (Vec::new(), Vec::new())
                });
                if !entry.0.iter().any(|l| l == line) {
                    entry.0.push(line.to_string());
                }
                current = Some(name);
            } else if line.starts_with('#') {
                if !trailing.iter().any(|l| l == line) {
                    trailing.push(line.to_string());
                }
            } else if let Some(fam) = &current {
                fams.get_mut(fam).expect("family exists").1.push(line.to_string());
            } else {
                preamble.push(line.to_string());
            }
        }
    }
    let mut out = String::new();
    for l in &preamble {
        out.push_str(l);
        out.push('\n');
    }
    for name in &order {
        let (meta, samples) = &fams[name];
        for l in meta {
            out.push_str(l);
            out.push('\n');
        }
        for l in samples {
            out.push_str(l);
            out.push('\n');
        }
    }
    for l in &trailing {
        out.push_str(l);
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_cluster(replicas: usize) -> Cluster {
        Cluster::builder()
            .model("mini_inception")
            .buckets(&[1, 4])
            .replicas(replicas)
            .route_p2c(7)
            .build()
            .expect("cluster builds")
    }

    #[test]
    fn builder_rejects_empty_specs() {
        let err = Cluster::builder().replicas(0).model("mini_inception").build();
        assert!(err.is_err(), "zero replicas must not build");
        let err = Cluster::builder().replicas(2).build();
        assert!(err.is_err(), "a cluster needs a source");
        let err = Cluster::builder()
            .model("mini_inception")
            .replicas(4)
            .max_replicas(2)
            .build();
        assert!(err.is_err(), "max_replicas below the initial count must not build");
    }

    #[test]
    fn cluster_serves_and_the_accounting_closes() {
        let cluster = mini_cluster(2);
        let n = cluster.example_len();
        let out_len = cluster.output_len();
        let mut tickets = Vec::new();
        for i in 0..12 {
            let req = InferRequest::new(vec![i as f32 / 16.0; n]);
            tickets.push(cluster.submit(req).expect("routable"));
        }
        for t in tickets {
            match t.outcome().expect("resolves") {
                InferOutcome::Output(v) => assert_eq!(v.len(), out_len),
                other => panic!("expected output, got {other:?}"),
            }
        }
        let report = cluster.shutdown().expect("drains");
        assert_eq!(report.submitted, 12);
        assert_eq!(report.router_shed, 0);
        assert_eq!(report.completed(), 12);
        assert!(report.accounting_closes(), "{}", report.render());
        assert_eq!(
            report.admitted_per_replica().iter().sum::<u64>(),
            12,
            "every request admitted exactly once"
        );
    }

    #[test]
    fn expired_requests_shed_at_the_door_without_touching_replicas() {
        let cluster = mini_cluster(2);
        let n = cluster.example_len();
        let req =
            InferRequest::new(vec![0.0; n]).deadline(Instant::now() - Duration::from_millis(1));
        let ticket = cluster.submit(req).expect("door shed still yields a ticket");
        assert_eq!(ticket.replica(), None, "door sheds never route");
        assert!(matches!(
            ticket.outcome().expect("resolves"),
            InferOutcome::DeadlineShed
        ));
        let report = cluster.shutdown().expect("drains");
        assert_eq!(report.router_shed, 1);
        assert_eq!(report.admitted_per_replica(), vec![0, 0]);
        assert!(report.accounting_closes(), "{}", report.render());
    }

    #[test]
    fn drained_replica_leaves_the_routable_set() {
        let cluster = mini_cluster(3);
        let n = cluster.example_len();
        let _ = cluster.infer(InferRequest::new(vec![0.5; n])).expect("serves");
        cluster.drain_replica(1).expect("drains");
        assert_eq!(cluster.live_replicas(), 2);
        assert_eq!(
            cluster.replica_states(),
            vec![ReplicaState::Live, ReplicaState::Retired, ReplicaState::Live]
        );
        // Post-drain traffic routes to the survivors only.
        let mut tickets = Vec::new();
        for _ in 0..8 {
            tickets.push(cluster.submit(InferRequest::new(vec![0.25; n])).unwrap());
        }
        for t in &tickets {
            assert_ne!(t.replica(), Some(1), "retired replica must not be routed to");
        }
        for t in tickets {
            assert!(matches!(t.outcome().unwrap(), InferOutcome::Output(_)));
        }
        // Double drain is an error, not a hang.
        assert!(cluster.drain_replica(1).is_err());
        let report = cluster.shutdown().expect("drains");
        assert!(report.accounting_closes(), "{}", report.render());
    }

    #[test]
    fn merge_expositions_keeps_one_header_per_family() {
        let a = "# HELP nimble_x total\n# TYPE nimble_x counter\nnimble_x{replica=\"0\"} 1\n"
            .to_string();
        let b = "# HELP nimble_x total\n# TYPE nimble_x counter\nnimble_x{replica=\"1\"} 2\n# HELP nimble_y gauge\n# TYPE nimble_y gauge\nnimble_y{replica=\"1\"} 3\n"
            .to_string();
        let merged = merge_expositions(&[a, b]);
        assert_eq!(merged.matches("# HELP nimble_x").count(), 1);
        assert_eq!(merged.matches("# TYPE nimble_x").count(), 1);
        assert!(merged.contains("nimble_x{replica=\"0\"} 1"));
        assert!(merged.contains("nimble_x{replica=\"1\"} 2"));
        assert!(merged.contains("# HELP nimble_y"));
        // Samples of a family stay contiguous: x samples before y's header.
        let y_at = merged.find("# HELP nimble_y").unwrap();
        let x1_at = merged.find("nimble_x{replica=\"1\"}").unwrap();
        assert!(x1_at < y_at, "family samples must be grouped:\n{merged}");
    }

    #[test]
    fn cluster_metrics_text_has_no_duplicate_series() {
        let cluster = Cluster::builder()
            .model("mini_inception")
            .buckets(&[1])
            .replicas(2)
            .telemetry()
            .build()
            .expect("cluster builds");
        let n = cluster.example_len();
        let _ = cluster.infer(InferRequest::new(vec![0.1; n])).expect("serves");
        let text = cluster.metrics_text().expect("telemetry on");
        let mut seen = std::collections::HashSet::new();
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let series = line.rsplit_once(' ').map(|(s, _)| s).unwrap_or(line);
            assert!(seen.insert(series.to_string()), "duplicate series {series}");
            assert!(series.contains("replica=\""), "unlabeled sample {line}");
        }
        let _ = cluster.shutdown().expect("drains");
    }
}
