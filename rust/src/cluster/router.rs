//! The replica router: deadline-aware, pressure-driven choice of which
//! live replica admits each request.
//!
//! The decision procedure is deliberately tiny and EXACTLY mirrored by
//! [`crate::sim::simulate_cluster`] — both sides call the same
//! `sim::cluster::p2c_draw` / `sim::cluster::lower_pressure` helpers, so
//! the routing of a seeded closed-loop run is reproducible offline
//! bit-for-bit (the `BENCH_cluster.json` exact entry pins it):
//!
//! - Requests already expired at the door are shed **before** routing
//!   and consume no RNG draw.
//! - One routable replica: chosen directly, no draw.
//! - Round-robin: a counter over the routable list, no draws.
//! - Power-of-two-choices: exactly two draws pick two *distinct*
//!   candidates from the routable list (ascending replica order); the
//!   one with the lower pressure score `(est, in_flight, index)` wins,
//!   where `est = ewma_queue_delay_s × in_flight` and ties break
//!   toward the lower replica index.

use crate::sim::cluster::{lower_pressure, p2c_draw};
use crate::util::Pcg32;

/// How the cluster router picks a replica
/// ([`ClusterBuilder::route_p2c`](super::ClusterBuilder::route_p2c) /
/// [`route_round_robin`](super::ClusterBuilder::route_round_robin)).
#[derive(Debug, Clone)]
pub enum RoutePolicy {
    /// Power-of-two-choices on per-replica pressure, seeded — the
    /// default (`seed 0`). Two random candidates, lower pressure wins.
    P2c { seed: u64 },
    /// Blind rotation over the routable replicas (the bench baseline
    /// p2c is judged against).
    RoundRobin,
}

impl Default for RoutePolicy {
    fn default() -> Self {
        RoutePolicy::P2c { seed: 0 }
    }
}

/// Mutable router state, serialized behind the cluster's one decision
/// mutex (decision order == submission order, the property the DES
/// mirror depends on).
pub(crate) struct RouterState {
    rng: Pcg32,
    rr: usize,
    p2c: bool,
}

impl RouterState {
    pub(crate) fn new(policy: &RoutePolicy) -> RouterState {
        match policy {
            RoutePolicy::P2c { seed } => {
                RouterState { rng: Pcg32::new(*seed), rr: 0, p2c: true }
            }
            RoutePolicy::RoundRobin => {
                RouterState { rng: Pcg32::new(0), rr: 0, p2c: false }
            }
        }
    }

    /// Choose one entry of `routable` (live replica indices, ascending).
    /// `pressure(replica_index)` supplies the score for p2c candidates;
    /// it is consulted only when a draw actually happens, so
    /// single-replica and round-robin decisions stay signal-free.
    pub(crate) fn choose(
        &mut self,
        routable: &[usize],
        pressure: impl Fn(usize) -> (f64, usize, usize),
    ) -> usize {
        debug_assert!(!routable.is_empty());
        if routable.len() == 1 {
            return routable[0];
        }
        if !self.p2c {
            let c = routable[self.rr % routable.len()];
            self.rr += 1;
            return c;
        }
        let (a, b) = p2c_draw(&mut self.rng, routable.len());
        lower_pressure(pressure(routable[a]), pressure(routable[b]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_rotates_over_the_routable_list_only() {
        let mut r = RouterState::new(&RoutePolicy::RoundRobin);
        let boom = |_: usize| -> (f64, usize, usize) { panic!("RR must not score") };
        // Replica 1 is drained out of the list: rotation covers 0, 2, 3.
        let routable = [0usize, 2, 3];
        let picks: Vec<usize> = (0..6).map(|_| r.choose(&routable, boom)).collect();
        assert_eq!(picks, vec![0, 2, 3, 0, 2, 3]);
    }

    #[test]
    fn single_candidate_consumes_no_draws() {
        let mut a = RouterState::new(&RoutePolicy::P2c { seed: 9 });
        let mut b = RouterState::new(&RoutePolicy::P2c { seed: 9 });
        let zero = |i: usize| (0.0, 0, i);
        // `a` routes three single-candidate decisions first; `b` none.
        for _ in 0..3 {
            assert_eq!(a.choose(&[5], zero), 5);
        }
        let routable = [0usize, 1, 2, 3];
        for _ in 0..16 {
            assert_eq!(
                a.choose(&routable, zero),
                b.choose(&routable, zero),
                "draw streams must not be perturbed by drawless decisions"
            );
        }
    }

    #[test]
    fn p2c_prefers_lower_pressure_and_breaks_ties_by_index() {
        let mut r = RouterState::new(&RoutePolicy::P2c { seed: 3 });
        // Replica 2 is heavily loaded: it must essentially never win.
        let skew = |i: usize| if i == 2 { (10.0, 7, i) } else { (0.0, 0, i) };
        let picks: Vec<usize> = (0..64).map(|_| r.choose(&[0, 1, 2], skew)).collect();
        assert!(picks.iter().all(|&p| p != 2), "loaded replica chosen: {picks:?}");
        // All-equal pressure: the winner is always the lower index of
        // the drawn pair, so replica 0 wins at least as often as 2.
        let mut r = RouterState::new(&RoutePolicy::P2c { seed: 3 });
        let zero = |i: usize| (0.0, 0, i);
        let picks: Vec<usize> = (0..96).map(|_| r.choose(&[0, 1, 2], zero)).collect();
        let count = |k: usize| picks.iter().filter(|&&p| p == k).count();
        assert!(count(0) >= count(2), "min-index tie-break: {:?}", (count(0), count(2)));
    }
}
