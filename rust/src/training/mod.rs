//! Training driver: replay the AOT `train_step` artifact (MLP forward +
//! backward + SGD fused into one XLA executable by `jax.value_and_grad`)
//! for a configurable number of steps on synthetic classification data,
//! logging the loss curve — the end-to-end training validation recorded in
//! EXPERIMENTS.md.
//!
//! Python built the step once; this loop is pure Rust: stage data, execute,
//! decompose the output tuple, feed the parameters back.

use anyhow::{Context, Result};
use std::time::Instant;

use crate::runtime::{artifacts_dir, ArtifactRegistry, RuntimeClient};
use crate::util::stats::{fmt_secs, Summary};
use crate::util::Pcg32;

/// Result of a training run.
pub struct TrainingReport {
    pub steps: usize,
    /// (step, loss) samples at the logging cadence.
    pub loss_curve: Vec<(usize, f32)>,
    pub first_loss: f32,
    pub final_loss: f32,
    pub step_time: Summary,
}

impl TrainingReport {
    pub fn render(&self) -> String {
        let mut s = format!(
            "trained {} steps: loss {:.4} → {:.4} ({:.1}% reduction)\n\
             step time: p50={} mean={}\nloss curve:\n",
            self.steps,
            self.first_loss,
            self.final_loss,
            (1.0 - self.final_loss / self.first_loss) * 100.0,
            fmt_secs(self.step_time.median()),
            fmt_secs(self.step_time.mean()),
        );
        for (step, loss) in &self.loss_curve {
            s.push_str(&format!("  step {step:>5}: {loss:.4}\n"));
        }
        s
    }
}

/// Run `steps` training steps, logging the loss every `log_every`.
pub fn run_training(steps: usize, log_every: usize) -> Result<TrainingReport> {
    let client = RuntimeClient::cpu()?;
    let registry = ArtifactRegistry::load(client, artifacts_dir())?;
    run_training_with(&registry, steps, log_every)
}

/// Same, over an existing registry.
pub fn run_training_with(
    registry: &ArtifactRegistry,
    steps: usize,
    log_every: usize,
) -> Result<TrainingReport> {
    let spec = registry.manifest.train.clone().context("no train artifact in manifest")?;
    let exe = registry.executable(&spec.artifact)?;

    // Initial parameters (the compile-time init saved by aot.py).
    let mut params: Vec<xla::PjRtBuffer> = (0..spec.n_params)
        .map(|i| {
            let (rel, _) = registry.manifest.weights[&format!("mlp_{i}")].clone();
            let arr = crate::runtime::npy::read_npy_f32(&artifacts_dir().join(rel))?;
            registry.client.buffer_f32(&arr.data, &arr.dims)
        })
        .collect::<Result<_>>()?;

    // Synthetic separable classification data: class-dependent means.
    let mut rng = Pcg32::new(2024);
    let n_batches = 8usize; // cycle through a small synthetic "dataset"
    let mut data = Vec::with_capacity(n_batches);
    for _ in 0..n_batches {
        let mut x = vec![0.0f32; spec.batch * spec.in_dim];
        let mut y = vec![0.0f32; spec.batch * spec.n_classes];
        for r in 0..spec.batch {
            let class = rng.gen_range(spec.n_classes);
            for c in 0..spec.in_dim {
                let mean = ((class * 31 + c) % 7) as f32 / 7.0 - 0.5;
                x[r * spec.in_dim + c] = mean + 0.3 * rng.gen_f32_range(-1.0, 1.0);
            }
            y[r * spec.n_classes + class] = 1.0;
        }
        let xb = registry.client.buffer_f32(&x, &[spec.batch, spec.in_dim])?;
        let yb = registry.client.buffer_f32(&y, &[spec.batch, spec.n_classes])?;
        data.push((xb, yb));
    }

    let mut loss_curve = Vec::new();
    let mut first_loss = None;
    let mut final_loss = 0.0f32;
    let mut times = Vec::with_capacity(steps);
    for step in 0..steps {
        let (xb, yb) = &data[step % n_batches];
        let t0 = Instant::now();
        let outs = {
            let mut args: Vec<&xla::PjRtBuffer> = params.iter().collect();
            args.push(xb);
            args.push(yb);
            exe.execute_b(&args)?
        };
        let tuple_lit = outs[0][0].to_literal_sync()?;
        let mut parts = tuple_lit.to_tuple().context("decomposing train outputs")?;
        times.push(t0.elapsed());
        anyhow::ensure!(parts.len() == spec.n_params + 1, "unexpected output arity");
        let loss_lit = parts.pop().unwrap();
        final_loss = loss_lit.to_vec::<f32>()?[0];
        anyhow::ensure!(final_loss.is_finite(), "loss diverged at step {step}");
        params = parts
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape()?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let host = lit.to_vec::<f32>()?;
                registry.client.buffer_f32(&host, &dims)
            })
            .collect::<Result<_>>()?;
        first_loss.get_or_insert(final_loss);
        if step % log_every == 0 || step + 1 == steps {
            loss_curve.push((step, final_loss));
        }
    }
    Ok(TrainingReport {
        steps,
        loss_curve,
        first_loss: first_loss.context("no steps run")?,
        final_loss,
        step_time: Summary::from_durations(&times),
    })
}
