//! Chrome-trace export of a *measured* run, plus the parse/diff half
//! of the measured-vs-predicted loop.
//!
//! Replay-op spans are emitted with exactly the slice schema
//! `sim::trace::to_chrome_trace` uses for the DES prediction —
//! `{"name", "ph": "X", "ts", "dur", "pid": 0, "tid": <stream>,
//! "args": {"submit_us"}}` — so a live trace and its prediction load
//! into Perfetto as two overlayable process rows and can be diffed
//! programmatically with [`diff_traces`]. Request-lifecycle and
//! lane/pool events ride along on `pid` 1 as instant events; ring
//! drop-oldest losses are declared in a metadata record so a consumer
//! can tell a short trace from a truncated one.

use std::collections::BTreeMap;

use super::{Event, EventKind, TelemetrySnapshot};
use crate::util::json::{parse_json, push_escaped, JsonValue};

/// Render a snapshot as a Chrome trace-event JSON array (µs units).
pub fn to_chrome_trace(snap: &TelemetrySnapshot, label: impl Fn(u32) -> String) -> String {
    let mut out = String::from("[\n");
    let mut first = true;
    let mut push = |line: &str, first: &mut bool| {
        if !*first {
            out.push_str(",\n");
        }
        *first = false;
        out.push_str(line);
    };
    for e in &snap.events {
        let ts = e.t0_ns as f64 / 1e3;
        let dur = e.t1_ns.saturating_sub(e.t0_ns) as f64 / 1e3;
        let line = match e.kind {
            EventKind::ReplayOp => {
                let mut name = String::new();
                push_escaped(&mut name, &label(e.op));
                format!(
                    "  {{\"name\": \"{}\", \"ph\": \"X\", \"ts\": {:.3}, \"dur\": {:.3}, \
                     \"pid\": 0, \"tid\": {}, \"args\": {{\"submit_us\": {:.3}}}}}",
                    name, ts, dur, e.stream, ts,
                )
            }
            _ => format!(
                "  {{\"name\": \"{}\", \"ph\": \"i\", \"s\": \"g\", \"ts\": {:.3}, \
                 \"pid\": 1, \"tid\": {}, \"args\": {{\"trace\": {}, \"aux\": {}, \
                 \"end_us\": {:.3}}}}}",
                e.kind.name(),
                ts,
                e.stream,
                e.trace,
                e.op,
                e.t1_ns as f64 / 1e3,
            ),
        };
        push(&line, &mut first);
    }
    if snap.dropped > 0 {
        push(
            &format!(
                "  {{\"name\": \"dropped_spans\", \"ph\": \"M\", \"pid\": 0, \"tid\": 0, \
                 \"args\": {{\"count\": {}}}}}",
                snap.dropped
            ),
            &mut first,
        );
    }
    out.push_str("\n]\n");
    out
}

/// One parsed trace record — the common subset of the sim exporter's
/// and the telemetry exporter's output.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSlice {
    pub name: String,
    pub ph: String,
    pub ts_us: f64,
    pub dur_us: f64,
    pub pid: u64,
    pub tid: u64,
}

/// Parse a Chrome trace-event JSON array back into slices.
pub fn parse_trace(json: &str) -> Result<Vec<TraceSlice>, String> {
    let doc = parse_json(json).map_err(|e| format!("trace: {e}"))?;
    let arr = doc.as_array().ok_or("trace: top level must be an array")?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, rec) in arr.iter().enumerate() {
        let name = rec
            .get("name")
            .and_then(JsonValue::as_str)
            .ok_or_else(|| format!("trace record {i}: missing \"name\""))?
            .to_string();
        let ph = rec
            .get("ph")
            .and_then(JsonValue::as_str)
            .ok_or_else(|| format!("trace record {i}: missing \"ph\""))?
            .to_string();
        out.push(TraceSlice {
            name,
            ph,
            ts_us: rec.get("ts").and_then(JsonValue::as_f64).unwrap_or(0.0),
            dur_us: rec.get("dur").and_then(JsonValue::as_f64).unwrap_or(0.0),
            pid: rec.get("pid").and_then(JsonValue::as_u64).unwrap_or(0),
            tid: rec.get("tid").and_then(JsonValue::as_u64).unwrap_or(0),
        });
    }
    Ok(out)
}

/// Dropped-span count declared by the trace's metadata record (0 when
/// the trace carries none).
pub fn dropped_span_count(slices: &[TraceSlice]) -> u64 {
    // The count lives in `args`, which TraceSlice doesn't keep; the
    // exporter also mirrors accounting into the snapshot, so here the
    // *presence* of the record is what matters to round-trip tests.
    slices.iter().filter(|s| s.ph == "M" && s.name == "dropped_spans").count() as u64
}

/// Per-op residual between a measured trace and its DES prediction.
#[derive(Debug, Clone, PartialEq)]
pub struct OpResidual {
    pub name: String,
    pub n_measured: usize,
    pub n_predicted: usize,
    /// Total duration across slices with this name, µs.
    pub measured_us: f64,
    pub predicted_us: f64,
    /// `measured - predicted` (µs); positive = measured ran longer.
    pub residual_us: f64,
}

/// Diff two traces op-by-op over their `"X"` slices. Names present in
/// only one side still get a row (the other side reads as zero), so
/// coverage gaps are visible, not silently dropped.
pub fn diff_traces(measured: &[TraceSlice], predicted: &[TraceSlice]) -> Vec<OpResidual> {
    fn fold(slices: &[TraceSlice]) -> BTreeMap<String, (usize, f64)> {
        let mut m: BTreeMap<String, (usize, f64)> = BTreeMap::new();
        for s in slices.iter().filter(|s| s.ph == "X") {
            let e = m.entry(s.name.clone()).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += s.dur_us;
        }
        m
    }
    let a = fold(measured);
    let b = fold(predicted);
    let mut names: Vec<&String> = a.keys().chain(b.keys()).collect();
    names.sort();
    names.dedup();
    names
        .into_iter()
        .map(|name| {
            let (n_measured, measured_us) = a.get(name).copied().unwrap_or((0, 0.0));
            let (n_predicted, predicted_us) = b.get(name).copied().unwrap_or((0, 0.0));
            OpResidual {
                name: name.clone(),
                n_measured,
                n_predicted,
                measured_us,
                predicted_us,
                residual_us: measured_us - predicted_us,
            }
        })
        .collect()
}

/// Human-readable residual table for the `nimble trace` CLI.
pub fn render_residuals(residuals: &[OpResidual]) -> String {
    let mut out = String::from(
        "op                               n_meas  n_pred   measured_us  predicted_us   residual_us\n",
    );
    for r in residuals {
        out.push_str(&format!(
            "{:<32} {:>6}  {:>6}  {:>12.3}  {:>12.3}  {:>12.3}\n",
            r.name, r.n_measured, r.n_predicted, r.measured_us, r.predicted_us, r.residual_us,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{RingStats, Telemetry};

    fn span(kind: EventKind, stream: u32, op: u32, t0: u64, t1: u64) -> Event {
        Event { kind, stream, op, trace: 0, t0_ns: t0, t1_ns: t1 }
    }

    fn snap(events: Vec<Event>, dropped: u64) -> TelemetrySnapshot {
        let emitted = events.len() as u64 + dropped;
        TelemetrySnapshot {
            recorded: events.len() as u64,
            rings: vec![RingStats { emitted, recorded: events.len() as u64, dropped }],
            emitted,
            dropped,
            events,
        }
    }

    #[test]
    fn export_parses_back_with_hostile_labels() {
        let s = snap(
            vec![
                span(EventKind::ReplayOp, 0, 0, 1_000, 3_500),
                span(EventKind::ReplayOp, 1, 1, 2_000, 2_000),
                span(EventKind::Admit, 3, 0, 500, 500),
            ],
            2,
        );
        let hostile = ["op\"zero\\one\ntwo".to_string(), "plain".to_string()];
        let trace = to_chrome_trace(&s, |op| hostile[op as usize].clone());
        let slices = parse_trace(&trace).expect("export must parse");
        assert_eq!(slices.len(), 4); // 2 ops + 1 instant + dropped metadata
        assert_eq!(dropped_span_count(&slices), 1);
        let ops: Vec<_> = slices.iter().filter(|s| s.ph == "X").collect();
        assert_eq!(ops.len(), 2);
        assert_eq!(ops[0].name, hostile[0]);
        assert!((ops[0].ts_us - 1.0).abs() < 1e-9);
        assert!((ops[0].dur_us - 2.5).abs() < 1e-9);
        assert_eq!(ops[0].pid, 0);
        assert_eq!(ops[0].tid, 0);
        // Zero-duration measured spans are kept, not dropped.
        assert!((ops[1].dur_us).abs() < 1e-9);
        let instants: Vec<_> = slices.iter().filter(|s| s.ph == "i").collect();
        assert_eq!(instants.len(), 1);
        assert_eq!(instants[0].name, "admit");
        assert_eq!(instants[0].pid, 1);
    }

    #[test]
    fn measured_schema_matches_sim_schema() {
        // Build a measured trace and a sim trace and check the X-slice
        // key set is identical — the overlay contract.
        let s = snap(vec![span(EventKind::ReplayOp, 2, 0, 0, 1_000)], 0);
        let measured = to_chrome_trace(&s, |_| "k".to_string());
        let line = measured.lines().find(|l| l.contains("\"ph\": \"X\"")).unwrap();
        for key in ["\"name\"", "\"ph\"", "\"ts\"", "\"dur\"", "\"pid\": 0", "\"tid\"", "\"submit_us\""]
        {
            assert!(line.contains(key), "missing {key} in {line}");
        }
    }

    #[test]
    fn diff_reports_residuals_and_coverage_gaps() {
        let measured = parse_trace(
            &to_chrome_trace(
                &snap(
                    vec![
                        span(EventKind::ReplayOp, 0, 0, 0, 3_000),
                        span(EventKind::ReplayOp, 0, 0, 5_000, 7_000),
                        span(EventKind::ReplayOp, 1, 1, 0, 1_000),
                    ],
                    0,
                ),
                |op| if op == 0 { "conv".into() } else { "only_measured".into() },
            ),
        )
        .unwrap();
        let predicted = vec![
            TraceSlice {
                name: "conv".into(),
                ph: "X".into(),
                ts_us: 0.0,
                dur_us: 4.0,
                pid: 0,
                tid: 0,
            },
            TraceSlice {
                name: "only_predicted".into(),
                ph: "X".into(),
                ts_us: 9.0,
                dur_us: 2.0,
                pid: 0,
                tid: 1,
            },
        ];
        let diff = diff_traces(&measured, &predicted);
        assert_eq!(diff.len(), 3);
        let conv = diff.iter().find(|r| r.name == "conv").unwrap();
        assert_eq!((conv.n_measured, conv.n_predicted), (2, 1));
        assert!((conv.measured_us - 5.0).abs() < 1e-9);
        assert!((conv.residual_us - 1.0).abs() < 1e-9);
        let gap = diff.iter().find(|r| r.name == "only_predicted").unwrap();
        assert_eq!(gap.n_measured, 0);
        let table = render_residuals(&diff);
        assert!(table.contains("conv") && table.contains("only_measured"));
    }

    #[test]
    fn live_telemetry_trace_round_trips() {
        use std::time::Instant;
        let tel = Telemetry::with_capacity(32);
        tel.register_labels(&["a", "b"]);
        let t0 = Instant::now();
        tel.replay_span(0, 0, t0, Instant::now());
        tel.replay_span(1, 1, t0, Instant::now());
        tel.event(EventKind::Kick, 0, 0, 7);
        let slices = parse_trace(&tel.chrome_trace()).expect("live trace parses");
        let snap = tel.snapshot();
        assert_eq!(slices.len(), snap.recorded as usize);
        assert_eq!(snap.recorded + snap.dropped, snap.emitted);
        assert_eq!(slices.iter().filter(|s| s.ph == "X").count(), 2);
    }
}
