//! Lock-free per-thread span ring: fixed capacity, drop-oldest, one
//! writer (the owning thread), any number of snapshot readers.
//!
//! Each slot is guarded by a per-slot sequence word (a seqlock): the
//! writer flips it odd before touching the payload and even after, so a
//! concurrent reader can detect and skip slots that are mid-write or
//! were lapped during the read. Recording is four relaxed stores plus
//! two release stores on the sequence word and one on the head — no
//! locks, no allocation, no CAS loop (single-writer rings don't need
//! one).
//!
//! Accounting closes structurally: `emitted` is the head counter,
//! `dropped = emitted.saturating_sub(capacity)`, and once the ring is
//! quiescent every one of the `emitted - dropped` newest events decodes
//! from a stable slot.

use std::sync::atomic::{AtomicU64, Ordering};

/// One recorded event, packed into four words by the caller
/// (`telemetry::pack_event` / `unpack_event` define the layout).
struct Slot {
    /// Seqlock word: 0 = never written, odd = write in progress,
    /// `2 * (generation + 1)` = stable payload from that generation.
    seq: AtomicU64,
    w: [AtomicU64; 4],
}

impl Slot {
    fn new() -> Self {
        Slot {
            seq: AtomicU64::new(0),
            w: [
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
            ],
        }
    }
}

/// A preallocated single-writer ring. Shared as `Arc<ThreadRing>`
/// between the owning thread (writer) and the telemetry registry
/// (reader); `record` must only ever be called from one thread at a
/// time, which the thread-local ownership in `telemetry::Telemetry`
/// guarantees.
pub struct ThreadRing {
    head: AtomicU64,
    slots: Box<[Slot]>,
}

/// Per-ring accounting exposed by snapshots:
/// `recorded + dropped == emitted` must close on every ring.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RingStats {
    /// Events ever written to this ring.
    pub emitted: u64,
    /// Events still decodable (stable slots recovered by the reader).
    pub recorded: u64,
    /// Events overwritten by drop-oldest.
    pub dropped: u64,
}

impl ThreadRing {
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        ThreadRing {
            head: AtomicU64::new(0),
            slots: (0..capacity).map(|_| Slot::new()).collect(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Record one packed event. Hot path: no allocation, no locking.
    #[inline]
    pub fn record(&self, w: [u64; 4]) {
        let cap = self.slots.len() as u64;
        let h = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(h % cap) as usize];
        let generation = h / cap;
        slot.seq.store(2 * generation + 1, Ordering::Release);
        slot.w[0].store(w[0], Ordering::Relaxed);
        slot.w[1].store(w[1], Ordering::Relaxed);
        slot.w[2].store(w[2], Ordering::Relaxed);
        slot.w[3].store(w[3], Ordering::Relaxed);
        slot.seq.store(2 * (generation + 1), Ordering::Release);
        self.head.store(h + 1, Ordering::Release);
    }

    /// Events ever emitted on this ring.
    pub fn emitted(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Decode every stable slot into `out`, returning the stats. Safe
    /// to call while the writer is live: torn or in-flight slots are
    /// skipped (they show up as neither recorded nor — until they
    /// finish — emitted-beyond-head). On a quiescent ring this recovers
    /// exactly `min(emitted, capacity)` events.
    pub fn drain_into(&self, out: &mut Vec<[u64; 4]>) -> RingStats {
        let cap = self.slots.len() as u64;
        let emitted = self.emitted();
        let mut recorded = 0u64;
        for (i, slot) in self.slots.iter().enumerate() {
            let seq1 = slot.seq.load(Ordering::Acquire);
            if seq1 == 0 || seq1 % 2 == 1 {
                continue; // never written, or write in progress
            }
            let w = [
                slot.w[0].load(Ordering::Relaxed),
                slot.w[1].load(Ordering::Relaxed),
                slot.w[2].load(Ordering::Relaxed),
                slot.w[3].load(Ordering::Relaxed),
            ];
            if slot.seq.load(Ordering::Acquire) != seq1 {
                continue; // lapped mid-read
            }
            // Reconstruct the event's global sequence number and check
            // it is one of the `emitted` events (guards a racing writer
            // that published seq before head became visible).
            let generation = seq1 / 2 - 1;
            let event_no = generation * cap + i as u64;
            if event_no >= emitted {
                continue;
            }
            recorded += 1;
            out.push(w);
        }
        RingStats { emitted, recorded, dropped: emitted.saturating_sub(recorded) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_closes_without_wrap() {
        let ring = ThreadRing::new(8);
        for i in 0..5u64 {
            ring.record([i, i + 1, i + 2, i + 3]);
        }
        let mut out = Vec::new();
        let stats = ring.drain_into(&mut out);
        assert_eq!(stats, RingStats { emitted: 5, recorded: 5, dropped: 0 });
        assert_eq!(out.len(), 5);
        assert!(out.iter().any(|w| w[0] == 0) && out.iter().any(|w| w[0] == 4));
    }

    #[test]
    fn drop_oldest_keeps_newest_and_accounting_closes() {
        let ring = ThreadRing::new(4);
        for i in 0..11u64 {
            ring.record([i, 0, 0, 0]);
        }
        let mut out = Vec::new();
        let stats = ring.drain_into(&mut out);
        assert_eq!(stats.emitted, 11);
        assert_eq!(stats.recorded, 4);
        assert_eq!(stats.dropped, 7);
        assert_eq!(stats.recorded + stats.dropped, stats.emitted);
        let mut kept: Vec<u64> = out.iter().map(|w| w[0]).collect();
        kept.sort_unstable();
        assert_eq!(kept, vec![7, 8, 9, 10]);
    }

    #[test]
    fn concurrent_reader_never_sees_torn_events() {
        use std::sync::Arc;
        let ring = Arc::new(ThreadRing::new(16));
        let writer = {
            let ring = Arc::clone(&ring);
            std::thread::spawn(move || {
                for i in 0..20_000u64 {
                    // All four words carry the same value: a torn read
                    // would surface as a mismatched tuple.
                    ring.record([i, i, i, i]);
                }
            })
        };
        let mut out = Vec::new();
        for _ in 0..200 {
            out.clear();
            let stats = ring.drain_into(&mut out);
            assert!(stats.recorded + stats.dropped == stats.emitted);
            for w in &out {
                assert!(w[0] == w[1] && w[1] == w[2] && w[2] == w[3], "torn read: {w:?}");
            }
        }
        writer.join().unwrap();
        out.clear();
        let stats = ring.drain_into(&mut out);
        assert_eq!(stats.emitted, 20_000);
        assert_eq!(stats.recorded, 16);
        assert_eq!(stats.recorded + stats.dropped, stats.emitted);
    }
}
