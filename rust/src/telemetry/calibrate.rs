//! Calibration pass: fold recorded replay-op spans into a
//! [`CostProfile`] the DES cost model consumes.
//!
//! Spans are grouped by op *label* (graph node name), because that is
//! the key `CostProfile::costs_for_graph` matches against, and
//! summarized as count / mean / p50 / p95. Only spans still resident
//! in the rings contribute — on a wrapped ring that is the newest
//! window, which for steady-state replay is also the most
//! representative one.

use std::collections::BTreeMap;

use super::{EventKind, TelemetrySnapshot};
use crate::sim::cost::{CostEntry, CostProfile};

/// Quantile by nearest-rank on an ascending-sorted slice.
fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Build a calibration profile from a snapshot's replay-op spans.
pub fn cost_profile(
    snap: &TelemetrySnapshot,
    label: impl Fn(u32) -> String,
) -> CostProfile {
    let mut by_name: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for e in snap.events.iter().filter(|e| e.kind == EventKind::ReplayOp) {
        by_name.entry(label(e.op)).or_default().push(e.duration_s());
    }
    let entries = by_name
        .into_iter()
        .map(|(name, mut durs)| {
            durs.sort_by(|a, b| a.total_cmp(b));
            let count = durs.len() as u64;
            let mean_s = durs.iter().sum::<f64>() / count as f64;
            CostEntry {
                name,
                count,
                mean_s,
                p50_s: quantile(&durs, 0.50),
                p95_s: quantile(&durs, 0.95),
            }
        })
        .collect();
    CostProfile { entries }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{Event, RingStats};

    fn op_span(op: u32, t0: u64, t1: u64) -> Event {
        Event { kind: EventKind::ReplayOp, stream: 0, op, trace: 0, t0_ns: t0, t1_ns: t1 }
    }

    #[test]
    fn spans_fold_into_per_op_statistics() {
        let events = vec![
            op_span(0, 0, 1_000),      // 1 µs
            op_span(0, 2_000, 5_000),  // 3 µs
            op_span(1, 0, 500),        // 0.5 µs
            Event {
                kind: EventKind::Admit,
                stream: 0,
                op: 0,
                trace: 1,
                t0_ns: 0,
                t1_ns: 0,
            },
        ];
        let emitted = events.len() as u64;
        let snap = TelemetrySnapshot {
            events,
            rings: vec![RingStats { emitted, recorded: emitted, dropped: 0 }],
            emitted,
            recorded: emitted,
            dropped: 0,
        };
        let profile = cost_profile(&snap, |op| format!("k{op}"));
        assert_eq!(profile.entries.len(), 2); // admit events don't calibrate
        let k0 = profile.entries.iter().find(|e| e.name == "k0").unwrap();
        assert_eq!(k0.count, 2);
        assert!((k0.mean_s - 2e-6).abs() < 1e-15);
        assert!((k0.p50_s - 3e-6).abs() < 1e-15); // nearest-rank of [1µs, 3µs] at q=.5
        assert!((k0.p95_s - 3e-6).abs() < 1e-15);
        assert_eq!(profile.duration_for("k1"), Some(5e-7));
        // And the profile survives its own JSON round trip.
        let back = CostProfile::from_json(&profile.to_json()).unwrap();
        assert_eq!(back.entries.len(), 2);
    }
}
