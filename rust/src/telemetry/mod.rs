//! Flight recorder: zero-alloc runtime tracing for the live stack.
//!
//! The DES can already draw a predicted timeline
//! ([`crate::sim::trace`]); this module records the *measured* one. A
//! [`Telemetry`] handle fans out to preallocated per-thread
//! [`ring::ThreadRing`]s — recording an event is an enabled check, a
//! counter bump, and a seqlock slot write: no locks, no allocation
//! after the first event a thread records (ring warmup), preserving
//! the executor's zero-alloc hot-path invariant. Three event families
//! are captured:
//!
//! * **replay-op spans** — stream, op, start/end around every kernel
//!   execution in [`crate::engine::executor`];
//! * **request lifecycle** — admit → EDF-stage → pop /
//!   shed{admission,staged,pop} → retry → reply, keyed by a per-ticket
//!   trace id minted at admission;
//! * **lane & pool events** — lane spawn/retire, dispatcher kicks,
//!   worker-pool steals, arena acquire/release.
//!
//! Read-side: [`Telemetry::snapshot`] decodes every stable slot
//! (accounting closes: `recorded + dropped == emitted` per ring),
//! [`Telemetry::chrome_trace`] exports the measured run in the *same*
//! slice schema as `sim::trace::to_chrome_trace` so live and predicted
//! timelines overlay in Perfetto, [`Telemetry::metrics_text`] exposes
//! Prometheus counters/gauges/histograms, and
//! [`Telemetry::cost_profile`] folds per-op span histograms into a
//! [`crate::sim::cost::CostProfile`] the DES consumes for calibration.
//!
//! Off by default everywhere: engines and lanes take
//! `Option<Telemetry>`, and `None` costs nothing.

pub mod calibrate;
pub mod chrome;
pub mod metrics;
pub mod ring;

pub use chrome::{diff_traces, parse_trace, render_residuals, OpResidual, TraceSlice};
pub use metrics::Metrics;
pub use ring::RingStats;

use std::cell::RefCell;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use ring::ThreadRing;

/// Default per-thread ring capacity (events). 16Ki events × 32 B/slot
/// payload ≈ 0.5 MiB per recording thread.
pub const DEFAULT_RING_CAPACITY: usize = 16 * 1024;

/// Number of [`EventKind`] variants (array-sized counters).
pub const N_EVENT_KINDS: usize = 15;

/// Everything the flight recorder knows how to record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    /// One kernel execution: `stream`, `op` = graph node, span times.
    ReplayOp = 0,
    /// Request admitted; mints the ticket's trace id.
    Admit = 1,
    /// Request staged into a forming batch by the EDF batcher.
    Stage = 2,
    /// Lane popped a formed batch (`op` = batch rows).
    Pop = 3,
    /// Shed at admission (queue-delay estimate ruled the budget out).
    ShedAdmission = 4,
    /// Shed from a staged batch by the expiry sweep.
    ShedStaged = 5,
    /// Shed at pop time (expired while queued/routed).
    ShedPop = 6,
    /// In-lane retry of a failed batch.
    Retry = 7,
    /// Reply delivered (span = enqueue → reply when times are known).
    Reply = 8,
    /// Lane thread spawned (`stream` = bucket).
    LaneSpawn = 9,
    /// Lane thread retired or detected dead (`stream` = bucket).
    LaneRetire = 10,
    /// Lane kicked the dispatcher awake.
    Kick = 11,
    /// Shared-pool worker stole onto a different replay job.
    Steal = 12,
    /// Arena lease acquired from the pool (`op` = KiB leased).
    ArenaAcquire = 13,
    /// Arena lease handed back.
    ArenaRelease = 14,
}

impl EventKind {
    pub fn from_u8(v: u8) -> Option<EventKind> {
        use EventKind::*;
        Some(match v {
            0 => ReplayOp,
            1 => Admit,
            2 => Stage,
            3 => Pop,
            4 => ShedAdmission,
            5 => ShedStaged,
            6 => ShedPop,
            7 => Retry,
            8 => Reply,
            9 => LaneSpawn,
            10 => LaneRetire,
            11 => Kick,
            12 => Steal,
            13 => ArenaAcquire,
            14 => ArenaRelease,
            _ => return None,
        })
    }

    /// Stable lower-snake name used in trace exports.
    pub fn name(&self) -> &'static str {
        use EventKind::*;
        match self {
            ReplayOp => "replay_op",
            Admit => "admit",
            Stage => "stage",
            Pop => "pop",
            ShedAdmission => "shed_admission",
            ShedStaged => "shed_staged",
            ShedPop => "shed_pop",
            Retry => "retry",
            Reply => "reply",
            LaneSpawn => "lane_spawn",
            LaneRetire => "lane_retire",
            Kick => "kick",
            Steal => "steal",
            ArenaAcquire => "arena_acquire",
            ArenaRelease => "arena_release",
        }
    }
}

/// One decoded event. Times are nanoseconds since the telemetry
/// handle's origin instant; instant events have `t0_ns == t1_ns`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    pub kind: EventKind,
    /// Stream id for replay ops; bucket id for serving/lane events.
    pub stream: u32,
    /// Graph node for replay ops; kind-specific payload otherwise
    /// (batch rows for `Pop`, KiB for `ArenaAcquire`, 0 elsewhere).
    pub op: u32,
    /// Per-ticket trace id (0 = not tied to a request).
    pub trace: u64,
    pub t0_ns: u64,
    pub t1_ns: u64,
}

impl Event {
    pub fn duration_s(&self) -> f64 {
        self.t1_ns.saturating_sub(self.t0_ns) as f64 / 1e9
    }
}

/// Pack an event into the ring's four payload words:
/// `w0 = kind | stream << 8 | op << 32`, `w1 = trace`, `w2/w3 = times`.
/// Streams above 2^24 wrap — far beyond any real stream/bucket count.
#[inline]
pub(crate) fn pack_event(
    kind: EventKind,
    stream: u32,
    op: u32,
    trace: u64,
    t0_ns: u64,
    t1_ns: u64,
) -> [u64; 4] {
    let w0 = kind as u64 | ((stream as u64 & 0x00FF_FFFF) << 8) | ((op as u64) << 32);
    [w0, trace, t0_ns, t1_ns]
}

pub(crate) fn unpack_event(w: [u64; 4]) -> Option<Event> {
    let kind = EventKind::from_u8((w[0] & 0xFF) as u8)?;
    Some(Event {
        kind,
        stream: ((w[0] >> 8) & 0x00FF_FFFF) as u32,
        op: (w[0] >> 32) as u32,
        trace: w[1],
        t0_ns: w[2],
        t1_ns: w[3],
    })
}

/// A read-side snapshot: decoded events (sorted by start time) plus
/// per-ring and total span accounting.
#[derive(Debug, Clone)]
pub struct TelemetrySnapshot {
    pub events: Vec<Event>,
    pub rings: Vec<RingStats>,
    pub emitted: u64,
    pub recorded: u64,
    pub dropped: u64,
}

struct TelemetryInner {
    /// Process-unique instance id — keys the thread-local ring cache.
    id: u64,
    ring_capacity: usize,
    origin: Instant,
    enabled: AtomicBool,
    next_trace: AtomicU64,
    rings: Mutex<Vec<Arc<ThreadRing>>>,
    /// Op-id → label registry for trace export (cold path only).
    labels: Mutex<Vec<String>>,
    /// Slow-path registrations (each allocates one ring): the
    /// "warmup" allocation counter the neutrality property watches.
    ring_allocs: AtomicU64,
    metrics: Metrics,
}

static NEXT_TELEMETRY_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// Per-thread cache of (telemetry id → ring). A linear scan: a
    /// thread records into at most a handful of telemetry instances.
    static TL_RINGS: RefCell<Vec<(u64, Arc<ThreadRing>)>> = const { RefCell::new(Vec::new()) };
}

/// Cloneable handle to one flight recorder. All clones share the same
/// rings, metrics, and trace-id counter.
#[derive(Clone)]
pub struct Telemetry {
    inner: Arc<TelemetryInner>,
}

impl fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Telemetry")
            .field("id", &self.inner.id)
            .field("enabled", &self.enabled())
            .field("ring_capacity", &self.inner.ring_capacity)
            .finish()
    }
}

impl Default for Telemetry {
    fn default() -> Self {
        Self::new()
    }
}

impl Telemetry {
    /// An enabled recorder with the default ring capacity.
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_RING_CAPACITY)
    }

    /// An enabled recorder with `ring_capacity` events per thread.
    pub fn with_capacity(ring_capacity: usize) -> Self {
        Telemetry {
            inner: Arc::new(TelemetryInner {
                id: NEXT_TELEMETRY_ID.fetch_add(1, Ordering::Relaxed),
                ring_capacity: ring_capacity.max(1),
                origin: Instant::now(),
                enabled: AtomicBool::new(true),
                next_trace: AtomicU64::new(0),
                rings: Mutex::new(Vec::new()),
                labels: Mutex::new(Vec::new()),
                ring_allocs: AtomicU64::new(0),
                metrics: Metrics::new(),
            }),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.inner.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.inner.enabled.store(on, Ordering::Relaxed);
    }

    /// Mint a fresh per-ticket trace id (≥ 1; 0 means "no trace").
    pub fn next_trace_id(&self) -> u64 {
        self.inner.next_trace.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Nanoseconds since this recorder's origin.
    #[inline]
    pub fn now_ns(&self) -> u64 {
        Instant::now().saturating_duration_since(self.inner.origin).as_nanos() as u64
    }

    #[inline]
    pub(crate) fn instant_ns(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.inner.origin).as_nanos() as u64
    }

    /// Record a span. Hot path after warmup: enabled check, counter
    /// bump, TLS scan, seqlock slot write — zero allocations.
    #[inline]
    pub fn record(
        &self,
        kind: EventKind,
        stream: u32,
        op: u32,
        trace: u64,
        t0_ns: u64,
        t1_ns: u64,
    ) {
        if !self.enabled() {
            return;
        }
        self.inner.metrics.kind_counts[kind as usize].fetch_add(1, Ordering::Relaxed);
        match kind {
            EventKind::LaneSpawn => {
                self.inner.metrics.lanes_live.fetch_add(1, Ordering::Relaxed);
            }
            EventKind::LaneRetire => {
                self.inner.metrics.lanes_live.fetch_sub(1, Ordering::Relaxed);
            }
            _ => {}
        }
        let w = pack_event(kind, stream, op, trace, t0_ns, t1_ns);
        let id = self.inner.id;
        let routed = TL_RINGS
            .try_with(|cell| {
                let rings = cell.borrow();
                for (rid, ring) in rings.iter() {
                    if *rid == id {
                        ring.record(w);
                        return true;
                    }
                }
                false
            })
            .unwrap_or_else(|_| {
                // Thread in teardown: count rather than lose silently.
                self.inner.metrics.unrouted.fetch_add(1, Ordering::Relaxed);
                true
            });
        if !routed {
            self.record_slow(w);
        }
    }

    /// First event this thread records against this instance: allocate
    /// and register its ring (the one-time "ring warmup" allocation).
    #[cold]
    fn record_slow(&self, w: [u64; 4]) {
        self.inner.ring_allocs.fetch_add(1, Ordering::Relaxed);
        let ring = Arc::new(ThreadRing::new(self.inner.ring_capacity));
        self.inner.rings.lock().expect("telemetry ring registry poisoned").push(Arc::clone(&ring));
        ring.record(w);
        let _ = TL_RINGS.try_with(|cell| {
            cell.borrow_mut().push((self.inner.id, ring));
        });
    }

    /// Record an instant (zero-duration) event stamped now.
    #[inline]
    pub fn event(&self, kind: EventKind, stream: u32, op: u32, trace: u64) {
        if !self.enabled() {
            return;
        }
        let t = self.now_ns();
        self.record(kind, stream, op, trace, t, t);
    }

    /// Record a replay-op span from two wall-clock instants and feed
    /// the per-op duration histogram.
    #[inline]
    pub fn replay_span(&self, stream: u32, op: u32, t0: Instant, t1: Instant) {
        if !self.enabled() {
            return;
        }
        let a = self.instant_ns(t0);
        let b = self.instant_ns(t1);
        self.inner.metrics.op_span.observe(b.saturating_sub(a) as f64 / 1e9);
        self.record(EventKind::ReplayOp, stream, op, 0, a, b);
    }

    /// Record a reply span (enqueue → reply) and feed the end-to-end
    /// latency histogram.
    #[inline]
    pub fn reply_span(&self, bucket: u32, trace: u64, enqueued: Instant, finished: Instant) {
        if !self.enabled() {
            return;
        }
        let a = self.instant_ns(enqueued);
        let b = self.instant_ns(finished);
        self.inner.metrics.latency.observe(b.saturating_sub(a) as f64 / 1e9);
        self.record(EventKind::Reply, bucket, 0, trace, a, b);
    }

    /// Slow-path ring registrations so far — allocations attributable
    /// to telemetry. Stops growing once every recording thread has
    /// warmed up.
    pub fn ring_allocs(&self) -> u64 {
        self.inner.ring_allocs.load(Ordering::Relaxed)
    }

    /// Register human-readable labels for op ids (cold path; used by
    /// trace export and calibration). Later registrations win only for
    /// ids that were still unnamed.
    pub fn register_labels<S: AsRef<str>>(&self, labels: &[S]) {
        let mut reg = self.inner.labels.lock().expect("telemetry label registry poisoned");
        if reg.len() < labels.len() {
            reg.resize(labels.len(), String::new());
        }
        for (i, l) in labels.iter().enumerate() {
            if reg[i].is_empty() {
                reg[i] = l.as_ref().to_string();
            }
        }
    }

    /// Label for an op id (falls back to `op<N>`).
    pub fn label_for(&self, op: u32) -> String {
        let reg = self.inner.labels.lock().expect("telemetry label registry poisoned");
        match reg.get(op as usize) {
            Some(l) if !l.is_empty() => l.clone(),
            _ => format!("op{op}"),
        }
    }

    /// Decode every ring into one time-sorted snapshot.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        let rings = self.inner.rings.lock().expect("telemetry ring registry poisoned");
        let mut raw = Vec::new();
        let mut stats = Vec::with_capacity(rings.len());
        for ring in rings.iter() {
            stats.push(ring.drain_into(&mut raw));
        }
        drop(rings);
        let mut events: Vec<Event> = raw.into_iter().filter_map(unpack_event).collect();
        events.sort_by_key(|e| (e.t0_ns, e.t1_ns, e.kind as u8));
        let emitted = stats.iter().map(|s| s.emitted).sum();
        let recorded = stats.iter().map(|s| s.recorded).sum();
        let dropped = stats.iter().map(|s| s.dropped).sum();
        TelemetrySnapshot { events, rings: stats, emitted, recorded, dropped }
    }

    /// Prometheus text exposition (snapshot-on-read).
    pub fn metrics_text(&self) -> String {
        let snap = self.snapshot();
        self.inner.metrics.prometheus_text(snap.emitted, snap.recorded, snap.dropped)
    }

    /// [`metrics_text`](Self::metrics_text) with a `key="value"` label
    /// pair injected into every sample — used by multi-runtime
    /// processes (one recorder per device replica) so merged
    /// expositions never collide series.
    pub fn metrics_text_labeled(&self, label: &str) -> String {
        let snap = self.snapshot();
        self.inner.metrics.prometheus_text_labeled(
            snap.emitted,
            snap.recorded,
            snap.dropped,
            label,
        )
    }

    /// Chrome-trace JSON of the measured run, using registered labels.
    pub fn chrome_trace(&self) -> String {
        let snap = self.snapshot();
        chrome::to_chrome_trace(&snap, |op| self.label_for(op))
    }

    /// Fold recorded replay-op spans into a calibration
    /// [`crate::sim::cost::CostProfile`].
    pub fn cost_profile(&self) -> crate::sim::cost::CostProfile {
        let snap = self.snapshot();
        calibrate::cost_profile(&snap, |op| self.label_for(op))
    }

    /// Direct metrics access (tests, gauges).
    pub fn metrics(&self) -> &Metrics {
        &self.inner.metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_round_trips_every_kind() {
        for k in 0..N_EVENT_KINDS as u8 {
            let kind = EventKind::from_u8(k).expect("kind");
            let w = pack_event(kind, 0xABCDE, 0xDEAD_BEEF, 77, 123, 456);
            let e = unpack_event(w).expect("unpack");
            assert_eq!(e.kind, kind);
            assert_eq!(e.stream, 0xABCDE);
            assert_eq!(e.op, 0xDEAD_BEEF);
            assert_eq!(e.trace, 77);
            assert_eq!((e.t0_ns, e.t1_ns), (123, 456));
        }
        assert!(EventKind::from_u8(N_EVENT_KINDS as u8).is_none());
    }

    #[test]
    fn snapshot_accounting_closes_across_threads() {
        let tel = Telemetry::with_capacity(64);
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let tel = tel.clone();
                std::thread::spawn(move || {
                    for i in 0..200u32 {
                        tel.record(EventKind::ReplayOp, t, i, 0, i as u64, i as u64 + 1);
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        let snap = tel.snapshot();
        assert_eq!(snap.rings.len(), 4);
        assert_eq!(snap.emitted, 800);
        for r in &snap.rings {
            assert_eq!(r.emitted, 200);
            assert_eq!(r.recorded, 64);
            assert_eq!(r.recorded + r.dropped, r.emitted);
        }
        assert_eq!(snap.events.len(), snap.recorded as usize);
        assert_eq!(snap.recorded + snap.dropped, snap.emitted);
        // Exactly one warmup allocation per recording thread.
        assert_eq!(tel.ring_allocs(), 4);
        // Counters agree with emission (they count every record call).
        assert_eq!(tel.metrics().count(EventKind::ReplayOp), 800);
    }

    #[test]
    fn disabled_recorder_records_nothing_and_allocates_nothing() {
        let tel = Telemetry::new();
        tel.set_enabled(false);
        tel.event(EventKind::Admit, 1, 0, 42);
        tel.replay_span(0, 0, Instant::now(), Instant::now());
        let snap = tel.snapshot();
        assert_eq!(snap.emitted, 0);
        assert_eq!(snap.events.len(), 0);
        assert_eq!(tel.ring_allocs(), 0);
        assert_eq!(tel.metrics().count(EventKind::Admit), 0);
    }

    #[test]
    fn trace_ids_are_unique_and_nonzero() {
        let tel = Telemetry::new();
        let a = tel.next_trace_id();
        let b = tel.next_trace_id();
        assert!(a >= 1 && b > a);
    }

    #[test]
    fn labels_register_and_fall_back() {
        let tel = Telemetry::new();
        tel.register_labels(&["matmul_0", "relu_1"]);
        assert_eq!(tel.label_for(0), "matmul_0");
        assert_eq!(tel.label_for(1), "relu_1");
        assert_eq!(tel.label_for(9), "op9");
        // First registration wins; gaps fill later.
        tel.register_labels(&["XXX", "relu_1", "add_2"]);
        assert_eq!(tel.label_for(0), "matmul_0");
        assert_eq!(tel.label_for(2), "add_2");
    }

    #[test]
    fn lanes_live_gauge_tracks_spawn_and_retire() {
        let tel = Telemetry::new();
        tel.event(EventKind::LaneSpawn, 4, 0, 0);
        tel.event(EventKind::LaneSpawn, 8, 0, 0);
        tel.event(EventKind::LaneRetire, 4, 0, 0);
        let text = tel.metrics_text();
        assert!(text.contains("nimble_lanes_live 1\n"), "{text}");
        assert!(text.contains("nimble_lanes_spawned_total 2\n"));
    }
}
