//! Telemetry metrics registry: fixed-shape counters, gauges, and
//! fixed-bucket histograms with Prometheus text exposition.
//!
//! Everything is preallocated at construction — observing a value is a
//! handful of relaxed atomic adds, so the registry can sit on the
//! executor's and lanes' hot paths without breaking the zero-alloc
//! invariant. Exposition (`prometheus_text`) snapshots the atomics at
//! read time; it never locks writers out.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

use super::{EventKind, N_EVENT_KINDS};

/// Upper bounds (seconds) for the latency histogram: 10 µs … 10 s in
/// roughly 1-2.5-5 decades, plus +Inf implicitly.
pub const LATENCY_BUCKETS_S: [f64; 14] = [
    10e-6, 25e-6, 50e-6, 100e-6, 250e-6, 500e-6, 1e-3, 2.5e-3, 5e-3, 10e-3, 25e-3, 100e-3,
    1.0, 10.0,
];

/// Upper bounds (seconds) for per-op replay spans: 250 ns … 100 ms.
pub const OP_BUCKETS_S: [f64; 12] = [
    250e-9, 1e-6, 2.5e-6, 5e-6, 10e-6, 25e-6, 50e-6, 100e-6, 250e-6, 1e-3, 10e-3, 100e-3,
];

/// A fixed-bucket histogram. Bucket counts are *non*-cumulative in
/// memory and cumulated at exposition time, Prometheus-style.
pub struct Histogram {
    bounds: &'static [f64],
    counts: Box<[AtomicU64]>,
    /// Overflow bucket (> last bound) — the `+Inf` bucket's exclusive
    /// share.
    inf: AtomicU64,
    sum_ns: AtomicU64,
    total: AtomicU64,
}

impl Histogram {
    fn new(bounds: &'static [f64]) -> Self {
        Histogram {
            bounds,
            counts: (0..bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            inf: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            total: AtomicU64::new(0),
        }
    }

    /// Record one observation in seconds. Zero-alloc, lock-free.
    #[inline]
    pub fn observe(&self, seconds: f64) {
        let mut hit = false;
        for (i, b) in self.bounds.iter().enumerate() {
            if seconds <= *b {
                self.counts[i].fetch_add(1, Ordering::Relaxed);
                hit = true;
                break;
            }
        }
        if !hit {
            self.inf.fetch_add(1, Ordering::Relaxed);
        }
        let ns = (seconds.max(0.0) * 1e9) as u64;
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn sum_seconds(&self) -> f64 {
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    fn render(&self, name: &str, help: &str, out: &mut String) {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
        let mut cum = 0u64;
        for (i, b) in self.bounds.iter().enumerate() {
            cum += self.counts[i].load(Ordering::Relaxed);
            out.push_str(&format!("{name}_bucket{{le=\"{b}\"}} {cum}\n"));
        }
        cum += self.inf.load(Ordering::Relaxed);
        out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n"));
        out.push_str(&format!("{name}_sum {}\n", self.sum_seconds()));
        out.push_str(&format!("{name}_count {}\n", self.count()));
    }
}

/// The registry: one counter per event kind (bumped by
/// `Telemetry::record` itself, so counters and the span ring can never
/// disagree about what was observed), a live-lanes gauge, span
/// accounting counters, and two histograms.
pub struct Metrics {
    pub(crate) kind_counts: [AtomicU64; N_EVENT_KINDS],
    pub(crate) lanes_live: AtomicI64,
    /// Events whose thread-local ring could not be reached (thread in
    /// teardown) — they are counted here instead of silently vanishing.
    pub(crate) unrouted: AtomicU64,
    pub latency: Histogram,
    pub op_span: Histogram,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            kind_counts: std::array::from_fn(|_| AtomicU64::new(0)),
            lanes_live: AtomicI64::new(0),
            unrouted: AtomicU64::new(0),
            latency: Histogram::new(&LATENCY_BUCKETS_S),
            op_span: Histogram::new(&OP_BUCKETS_S),
        }
    }

    pub fn count(&self, kind: EventKind) -> u64 {
        self.kind_counts[kind as usize].load(Ordering::Relaxed)
    }

    /// Render the whole registry in Prometheus text exposition format.
    /// `emitted`/`recorded`/`dropped` are the ring totals supplied by
    /// the telemetry snapshot so span accounting is scrapeable too.
    pub fn prometheus_text(&self, emitted: u64, recorded: u64, dropped: u64) -> String {
        let mut out = String::with_capacity(4096);
        let mut counter = |name: &str, help: &str, v: u64| {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"
            ));
        };
        counter(
            "nimble_replay_ops_total",
            "Replay-op spans recorded by the executor.",
            self.count(EventKind::ReplayOp),
        );
        counter(
            "nimble_requests_admitted_total",
            "Requests admitted into the serving queue.",
            self.count(EventKind::Admit),
        );
        counter(
            "nimble_requests_staged_total",
            "Requests staged into a batch by the EDF batcher.",
            self.count(EventKind::Stage),
        );
        counter(
            "nimble_batches_popped_total",
            "Batches popped by lane threads.",
            self.count(EventKind::Pop),
        );
        counter(
            "nimble_retries_total",
            "In-lane retries of failed batches.",
            self.count(EventKind::Retry),
        );
        counter(
            "nimble_replies_total",
            "Request replies delivered to clients.",
            self.count(EventKind::Reply),
        );
        counter(
            "nimble_lanes_spawned_total",
            "Lane threads ever spawned.",
            self.count(EventKind::LaneSpawn),
        );
        counter(
            "nimble_lanes_retired_total",
            "Lane threads retired or detected dead.",
            self.count(EventKind::LaneRetire),
        );
        counter(
            "nimble_kicks_total",
            "Dispatcher wakeup kicks from lanes.",
            self.count(EventKind::Kick),
        );
        counter(
            "nimble_steals_total",
            "Cross-job steals in the shared worker pool.",
            self.count(EventKind::Steal),
        );
        counter(
            "nimble_arena_acquires_total",
            "Arena leases acquired from the pool.",
            self.count(EventKind::ArenaAcquire),
        );
        counter(
            "nimble_arena_releases_total",
            "Arena leases handed back to the pool.",
            self.count(EventKind::ArenaRelease),
        );
        counter(
            "nimble_spans_emitted_total",
            "Events emitted across all rings (recorded + dropped).",
            emitted,
        );
        counter(
            "nimble_spans_recorded_total",
            "Events still resident in the rings.",
            recorded,
        );
        counter(
            "nimble_spans_dropped_total",
            "Events overwritten by drop-oldest ring wrap.",
            dropped,
        );
        counter(
            "nimble_spans_unrouted_total",
            "Events observed while the thread-local ring was unreachable.",
            self.unrouted.load(Ordering::Relaxed),
        );
        // Labeled shed counter: one family, three stages.
        out.push_str(
            "# HELP nimble_deadline_shed_total Requests shed, by pipeline stage.\n\
             # TYPE nimble_deadline_shed_total counter\n",
        );
        out.push_str(&format!(
            "nimble_deadline_shed_total{{stage=\"admission\"}} {}\n",
            self.count(EventKind::ShedAdmission)
        ));
        out.push_str(&format!(
            "nimble_deadline_shed_total{{stage=\"staged\"}} {}\n",
            self.count(EventKind::ShedStaged)
        ));
        out.push_str(&format!(
            "nimble_deadline_shed_total{{stage=\"pop\"}} {}\n",
            self.count(EventKind::ShedPop)
        ));
        out.push_str(
            "# HELP nimble_lanes_live Lane threads currently live.\n\
             # TYPE nimble_lanes_live gauge\n",
        );
        out.push_str(&format!(
            "nimble_lanes_live {}\n",
            self.lanes_live.load(Ordering::Relaxed)
        ));
        self.latency.render(
            "nimble_request_latency_seconds",
            "End-to-end request latency (enqueue to reply).",
            &mut out,
        );
        self.op_span.render(
            "nimble_replay_op_seconds",
            "Per-op replay span duration.",
            &mut out,
        );
        out
    }

    /// [`prometheus_text`](Self::prometheus_text) with an extra label
    /// pair (e.g. `replica="2"`) injected into every sample so several
    /// registries can merge into one exposition without colliding
    /// series — the multi-runtime fix for processes that scrape more
    /// than one [`Metrics`].
    pub fn prometheus_text_labeled(
        &self,
        emitted: u64,
        recorded: u64,
        dropped: u64,
        label: &str,
    ) -> String {
        inject_label(&self.prometheus_text(emitted, recorded, dropped), label)
    }
}

/// Inject one `key="value"` label pair into every sample line of a
/// Prometheus text exposition (comment lines pass through). Labeled
/// samples get the pair prepended to their label set; bare samples get
/// a label set.
pub(crate) fn inject_label(text: &str, label: &str) -> String {
    let mut out = String::with_capacity(text.len() + 64);
    for line in text.lines() {
        if line.starts_with('#') || line.is_empty() {
            out.push_str(line);
        } else if let Some(brace) = line.find('{') {
            out.push_str(&line[..brace + 1]);
            out.push_str(label);
            out.push(',');
            out.push_str(&line[brace + 1..]);
        } else if let Some(space) = line.find(' ') {
            out.push_str(&line[..space]);
            out.push('{');
            out.push_str(label);
            out.push('}');
            out.push_str(&line[space..]);
        } else {
            out.push_str(line);
        }
        out.push('\n');
    }
    out
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_cumulate_and_account() {
        let h = Histogram::new(&LATENCY_BUCKETS_S);
        h.observe(5e-6); // first bucket
        h.observe(40e-6); // le=50µs
        h.observe(99.0); // +Inf only
        assert_eq!(h.count(), 3);
        let mut out = String::new();
        h.render("t", "test", &mut out);
        assert!(out.contains("t_bucket{le=\"0.00001\"} 1\n"));
        assert!(out.contains("t_bucket{le=\"+Inf\"} 3\n"));
        assert!(out.contains("t_count 3\n"));
    }

    #[test]
    fn labeled_exposition_tags_every_sample_and_spares_comments() {
        let m = Metrics::new();
        m.kind_counts[EventKind::Admit as usize].fetch_add(2, Ordering::Relaxed);
        m.latency.observe(1e-3);
        let text = m.prometheus_text_labeled(3, 3, 0, "replica=\"1\"");
        assert!(text.contains("nimble_requests_admitted_total{replica=\"1\"} 2\n"));
        assert!(
            text.contains("nimble_deadline_shed_total{replica=\"1\",stage=\"admission\"} 0\n"),
            "labeled families must get the pair prepended: {text}"
        );
        assert!(text.contains("# TYPE nimble_requests_admitted_total counter\n"));
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.contains("replica=\"1\""),
                "unlabeled sample in labeled exposition: {line}"
            );
        }
    }

    #[test]
    fn exposition_is_well_formed() {
        let m = Metrics::new();
        m.kind_counts[EventKind::Admit as usize].fetch_add(2, Ordering::Relaxed);
        m.latency.observe(1e-3);
        let text = m.prometheus_text(7, 5, 2);
        assert!(text.contains("nimble_requests_admitted_total 2\n"));
        assert!(text.contains("nimble_spans_emitted_total 7\n"));
        assert!(text.contains("nimble_deadline_shed_total{stage=\"admission\"} 0\n"));
        // Every line is either a comment or `name{labels} value`.
        for line in text.lines() {
            assert!(
                line.starts_with('#')
                    || line.split_whitespace().count() == 2
                    || line.contains("{"),
                "odd exposition line: {line}"
            );
        }
    }
}
