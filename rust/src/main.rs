//! `nimble` CLI — leader entrypoint.
//!
//! Subcommands:
//!   figures [all|fig2a|fig2b|fig2c|fig7|table1|fig8|fig9|fig10]
//!                         regenerate the paper's tables/figures (VGPU)
//!   models                list the model zoo (ops, MACs, Deg., streams)
//!   assign <model>        run Algorithm 1 on a model and report the plan
//!   replay <model> [--iters K]
//!                         compile the model's replay tape and run the
//!                         parallel multi-stream executor vs the serial
//!                         oracle, with the DES speedup prediction
//!   sim <model> <system>  one simulated inference run in detail
//!   trace <model> [--iters K] [--out DIR]
//!                         run a measured replay with the telemetry
//!                         flight recorder attached, write the Chrome
//!                         trace / Prometheus metrics / calibrated cost
//!                         profile, and diff measured vs DES-predicted
//!                         per-op timings
//!   verify <model>|--all [--batch N] [--single] [--json PATH]
//!          [--out DIR] [--inject drop-sync|retarget-wait|swap-streams|
//!          shrink-offset --seed S]
//!                         statically certify the compiled replay tape +
//!                         arena plan (races, deadlocks, aliasing,
//!                         well-formedness) and print the diagnostic
//!                         table with witnesses; --all sweeps the model
//!                         zoo and writes per-model JSON reports;
//!                         --inject demonstrates the seeded plan mutator
//!   infer [--batch N] [--iters K] [--mode replay|eager]   (feature xla)
//!                         run MiniInception on the real XLA path
//!   serve [--requests N] [--rate RPS] [--deadline-ms D]
//!         [--mode replay|eager (feature xla) | --model NAME (tape path)]
//!                         batched serving demo through the Runtime
//!                         façade: the real XLA path with the feature,
//!                         tape-backed lanes without it
//!   train [--steps N]     run the AOT train-step artifact   (feature xla)
//!   cluster [--replicas N] [--requests N] [--rate RPS] [--deadline-ms D]
//!           [--round-robin] [--drain IDX] [--model NAME]
//!                         data-parallel replica-group demo: N tape-backed
//!                         replicas behind the deadline-aware p2c router,
//!                         optional mid-run drain of one replica, with the
//!                         cluster DES (`sim::simulate_cluster`) prediction
//!                         printed next to the measured run

// Same unsafe-hygiene bar as the library crate (this binary has no
// unsafe code; the lints keep it that way).
#![deny(unsafe_op_in_unsafe_fn)]
#![deny(clippy::undocumented_unsafe_blocks)]

use anyhow::{bail, Context, Result};
use nimble::baselines::Baseline;
use nimble::matching::MatchingAlgo;
use nimble::models;
use nimble::ops::op::total_macs;
use nimble::sim::GpuSpec;
use nimble::stream::{assign_streams, logical_concurrency_degree, plan_syncs};
use nimble::util::stats::fmt_secs;
use nimble::util::table::Table;
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("figures") => cmd_figures(args.get(1).map(String::as_str).unwrap_or("all")),
        Some("models") => cmd_models(),
        Some("assign") => {
            cmd_assign(args.get(1).map(String::as_str).context("usage: nimble assign <model>")?)
        }
        Some("replay") => cmd_replay(args),
        Some("sim") => cmd_sim(
            args.get(1).map(String::as_str).context("usage: nimble sim <model> <system>")?,
            args.get(2).map(String::as_str).unwrap_or("Nimble"),
        ),
        Some("trace") => cmd_trace(args),
        Some("verify") => cmd_verify(args),
        Some("infer") => cmd_infer(args),
        Some("serve") => cmd_serve(args),
        Some("train") => cmd_train(args),
        Some("cluster") => cmd_cluster(args),
        Some(other) => bail!("unknown subcommand `{other}` — run without args for usage"),
        None => {
            println!(
                "nimble — reproduction of Nimble (NeurIPS 2020)\n\n\
                 usage: nimble <figures|models|assign|replay|sim|trace|verify|infer|serve|train|cluster> [args]\n\
                 see rust/src/main.rs docs for details"
            );
            Ok(())
        }
    }
}

fn cmd_figures(which: &str) -> Result<()> {
    let dir = std::path::PathBuf::from("results");
    let figs = nimble::figures::run(which, &dir)?;
    for (name, table) in figs {
        println!("== {name} ==\n{}", table.render());
    }
    println!("(TSV written to results/)");
    Ok(())
}

fn cmd_models() -> Result<()> {
    let mut t = Table::new(vec!["model", "ops", "edges", "GMACs", "Deg.", "streams", "syncs"]);
    for spec in models::MODELS {
        let g = models::build(spec.name, 1);
        let a = assign_streams(&g, MatchingAlgo::HopcroftKarp);
        t.row(vec![
            spec.name.to_string(),
            g.n_nodes().to_string(),
            g.n_edges().to_string(),
            format!("{:.2}", total_macs(&g) as f64 / 1e9),
            logical_concurrency_degree(&g).to_string(),
            a.n_streams.to_string(),
            a.min_syncs().to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_assign(model: &str) -> Result<()> {
    let g = models::build(model, 1);
    let start = Instant::now();
    let a = assign_streams(&g, MatchingAlgo::HopcroftKarp);
    let plan = plan_syncs(&a);
    let took = start.elapsed();
    println!(
        "model {model}: |V|={} |E|={} |E'|={} |M|={}\n\
         streams={} syncs={} (theorem 3: |E'|-|M|={})\n\
         degree of logical concurrency: {}\n\
         assignment time: {}",
        g.n_nodes(),
        g.n_edges(),
        a.meg.n_edges(),
        a.matching_size,
        a.n_streams,
        plan.n_syncs(),
        a.meg.n_edges() - a.matching_size,
        logical_concurrency_degree(&g),
        fmt_secs(took.as_secs_f64()),
    );
    Ok(())
}

/// Compile a model to a replay tape and drive the parallel executor.
fn cmd_replay(args: &[String]) -> Result<()> {
    use nimble::aot::tape::ReplayTape;
    use nimble::engine::executor::{ReplayContext, SyntheticKernel};
    use nimble::sim::{kernel_cost, simulate_tape, HostProfile};
    use nimble::stream::rewrite::{rewrite, rewrite_single_stream};
    use nimble::util::{Pcg32, Summary};

    let model = args
        .get(1)
        .map(String::as_str)
        .context("usage: nimble replay <model> [--iters K]")?;
    let iters: usize = flag(args, "--iters").map(|s| s.parse()).transpose()?.unwrap_or(20);
    let g = models::build(model, 1);
    let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
    let tape = ReplayTape::for_op_graph(&g, &plan, 4096);
    println!(
        "{model}: {} tasks on {} streams, {} events, {} slots",
        tape.n_tasks(),
        tape.n_streams(),
        tape.n_events(),
        tape.n_slots()
    );

    let input: Vec<f32> = {
        let mut rng = Pcg32::new(42);
        (0..tape.input_slots()[0].1).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()
    };
    let mut par = ReplayContext::new(tape.clone(), SyntheticKernel);
    let mut ser = ReplayContext::new(tape.clone(), SyntheticKernel);
    println!(
        "reserved memory: arena {} B (unshared {} B, {:.1}% saved by stream-aware aliasing)",
        par.reserved_bytes(),
        par.unshared_bytes(),
        100.0 * (1.0 - par.reserved_bytes() as f64 / par.unshared_bytes().max(1) as f64),
    );
    par.replay_one(&input).map_err(anyhow::Error::msg)?;
    ser.replay_serial(&[&input]).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(par.output() == ser.output(), "parallel and serial outputs diverged");
    println!("differential: parallel output bit-identical to serial ✓");

    let mut t_par = Vec::with_capacity(iters);
    let mut t_ser = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        par.replay_one(&input).map_err(anyhow::Error::msg)?;
        t_par.push(t0.elapsed().as_secs_f64());
        let t0 = Instant::now();
        ser.replay_serial(&[&input]).map_err(anyhow::Error::msg)?;
        t_ser.push(t0.elapsed().as_secs_f64());
    }
    let (sp, ss) = (Summary::from_samples(t_par), Summary::from_samples(t_ser));
    println!(
        "host wall time (synthetic kernels, {iters} iters): parallel p50 {}  serial p50 {}",
        fmt_secs(sp.median()),
        fmt_secs(ss.median()),
    );

    // DES prediction over the same tapes on a V100-class device.
    let dev = GpuSpec::v100();
    let costs: Vec<_> = (0..g.n_nodes()).map(|v| kernel_cost(g.node(v), &dev)).collect();
    let single = ReplayTape::for_op_graph(&g, &rewrite_single_stream(&g), 4096);
    let multi_s = simulate_tape(&tape, &costs, HostProfile::nimble(), dev.clone()).total_s;
    let single_s = simulate_tape(&single, &costs, HostProfile::nimble(), dev).total_s;
    println!(
        "DES prediction (V100): single-stream {}  multi-stream {}  speedup {:.2}x",
        fmt_secs(single_s),
        fmt_secs(multi_s),
        single_s / multi_s
    );
    Ok(())
}

/// `nimble trace`: the measured-vs-predicted loop. Replays a model with
/// the flight recorder attached, writes the three observability
/// artifacts (Chrome trace, Prometheus metrics, calibrated cost
/// profile), then feeds the profile back through `sim::cost` into the
/// DES and prints per-op residuals between the measured trace and the
/// prediction.
fn cmd_trace(args: &[String]) -> Result<()> {
    use nimble::aot::tape::ReplayTape;
    use nimble::engine::executor::{ExecOptions, ReplayContext, SyntheticKernel};
    use nimble::sim::cost::CostProfile;
    use nimble::sim::{simulate_tape, HostProfile};
    use nimble::stream::rewrite::rewrite;
    use nimble::telemetry::{diff_traces, parse_trace, render_residuals, OpResidual, Telemetry};
    use nimble::util::Pcg32;

    let model = args
        .get(1)
        .map(String::as_str)
        .context("usage: nimble trace <model> [--iters K] [--out DIR]")?;
    let iters: usize = flag(args, "--iters").map(|s| s.parse()).transpose()?.unwrap_or(10);
    let out_dir = flag(args, "--out").unwrap_or_else(|| "results".to_string());

    let g = models::build(model, 1);
    let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
    let tape = ReplayTape::for_op_graph(&g, &plan, 4096);

    // Ring sized for every span across all iterations, so the residual
    // table reflects a complete trace rather than a drop-oldest window.
    let telemetry = Telemetry::with_capacity((g.n_nodes() * (iters + 1)).next_power_of_two());
    let labels: Vec<String> = (0..g.n_nodes()).map(|v| g.node(v).name.clone()).collect();
    telemetry.register_labels(&labels);

    let mut ctx = ReplayContext::with_options(
        tape.clone(),
        SyntheticKernel,
        ExecOptions { telemetry: Some(telemetry.clone()), ..Default::default() },
    );
    let input: Vec<f32> = {
        let mut rng = Pcg32::new(42);
        (0..tape.input_slots()[0].1).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()
    };
    for _ in 0..iters {
        ctx.replay_one(&input).map_err(anyhow::Error::msg)?;
    }
    let snap = telemetry.snapshot();
    println!(
        "{model}: {} tasks on {} streams, {iters} replays — {} spans recorded \
         ({} dropped of {} emitted)",
        tape.n_tasks(),
        tape.n_streams(),
        snap.recorded,
        snap.dropped,
        snap.emitted,
    );

    // The three artifacts: measured trace, metrics, calibration profile.
    let trace_json = telemetry.chrome_trace();
    let profile_json = telemetry.cost_profile().to_json();
    let dir = std::path::Path::new(&out_dir);
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{model}_trace.json")), &trace_json)?;
    std::fs::write(dir.join(format!("{model}_metrics.prom")), telemetry.metrics_text())?;
    std::fs::write(dir.join(format!("{model}_cost_profile.json")), &profile_json)?;
    println!(
        "wrote {0}/{model}_trace.json {0}/{model}_metrics.prom {0}/{model}_cost_profile.json",
        out_dir
    );

    // Round-trip the profile through JSON into the DES cost model and
    // predict the same tape on a V100-class device.
    let profile = CostProfile::from_json(&profile_json).map_err(anyhow::Error::msg)?;
    let dev = GpuSpec::v100();
    let costs = profile.costs_for_graph(&g, &dev);
    let predicted = simulate_tape(&tape, &costs, HostProfile::nimble(), dev);
    let predicted_json = nimble::sim::trace::to_chrome_trace(&predicted, |n| {
        if n < g.n_nodes() {
            g.node(n).name.clone()
        } else {
            format!("task{n}")
        }
    });

    // Diff on per-instance means: the measured side carries `iters`
    // slices per op, the prediction one, so raw totals would compare
    // different sample counts.
    let measured = parse_trace(&trace_json).map_err(anyhow::Error::msg)?;
    let predicted_slices = parse_trace(&predicted_json).map_err(anyhow::Error::msg)?;
    let residuals: Vec<OpResidual> = diff_traces(&measured, &predicted_slices)
        .into_iter()
        .map(|r| {
            let m = r.measured_us / r.n_measured.max(1) as f64;
            let p = r.predicted_us / r.n_predicted.max(1) as f64;
            OpResidual { measured_us: m, predicted_us: p, residual_us: m - p, ..r }
        })
        .collect();
    println!("\nper-op residuals (per-instance mean µs, measured − predicted):");
    print!("{}", render_residuals(&residuals));
    println!(
        "\nDES total with calibrated costs: {} (overlay both JSON files in Perfetto)",
        fmt_secs(predicted.total_s)
    );
    Ok(())
}

/// `nimble verify`: static plan certification. Compiles a model's
/// replay tape + arena plan exactly as the serving build path does and
/// runs the AoT verifier (`aot::verify`) over them, printing the
/// diagnostic table with witness interleavings and optionally a
/// machine-readable JSON report. `--all` sweeps the model zoo (CI runs
/// this and archives the reports); `--inject` applies one seeded
/// mutation first to demonstrate the analyzer catching a planted bug.
fn cmd_verify(args: &[String]) -> Result<()> {
    use nimble::aot::memory::{happens_before_conflicts, plan_with_conflicts, ArenaPlan};
    use nimble::aot::tape::ReplayTape;
    use nimble::aot::verify::mutate::{mutate, MutationKind};
    use nimble::aot::verify::verify_with_arena;
    use nimble::stream::rewrite::{rewrite, rewrite_single_stream};
    use nimble::util::Pcg32;

    let usage = "usage: nimble verify <model>|--all [--batch N] [--single] [--json PATH] \
                 [--out DIR] [--inject CLASS --seed S]";
    let batch: usize = flag(args, "--batch").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let single = args.iter().any(|a| a == "--single");

    let compile = |model: &str| -> Result<(ReplayTape, ArenaPlan)> {
        let g = models::build(model, batch);
        let plan = if single {
            rewrite_single_stream(&g)
        } else {
            rewrite(&g, MatchingAlgo::HopcroftKarp)
        };
        let tape = ReplayTape::for_op_graph(&g, &plan, 4096);
        let arena = plan_with_conflicts(&tape.slot_bytes(), &happens_before_conflicts(&tape));
        Ok((tape, arena))
    };

    if args.iter().any(|a| a == "--all") {
        let out_dir = flag(args, "--out");
        if let Some(dir) = &out_dir {
            std::fs::create_dir_all(dir)?;
        }
        let mut t = Table::new(vec![
            "model", "records", "streams", "events", "hb edges", "alias pairs", "diags",
        ]);
        let mut dirty = 0usize;
        for spec in models::MODELS {
            let (tape, arena) = compile(spec.name)?;
            let report = verify_with_arena(&tape, &arena);
            dirty += usize::from(!report.is_clean());
            t.row(vec![
                spec.name.to_string(),
                report.n_ops.to_string(),
                report.n_streams.to_string(),
                report.n_events.to_string(),
                report.hb_edges.to_string(),
                report.alias_pairs_checked.to_string(),
                report.diagnostics.len().to_string(),
            ]);
            if let Some(dir) = &out_dir {
                let path = std::path::Path::new(dir).join(format!("{}_verify.json", spec.name));
                std::fs::write(&path, report.to_json())?;
            }
            if !report.is_clean() {
                println!("== {} ==\n{}", spec.name, report.render());
            }
        }
        println!("{}", t.render());
        if let Some(dir) = &out_dir {
            println!("(JSON reports written to {dir}/)");
        }
        anyhow::ensure!(dirty == 0, "{dirty} model(s) failed static plan verification");
        println!("model zoo: every compiled plan verified clean ✓");
        return Ok(());
    }

    let model = args.get(1).filter(|a| !a.starts_with("--")).context(usage)?;
    let (mut tape, mut arena) = compile(model)?;
    if let Some(class) = flag(args, "--inject") {
        let kind = match class.as_str() {
            "drop-sync" => MutationKind::DropSync,
            "retarget-wait" => MutationKind::RetargetWait,
            "swap-streams" => MutationKind::SwapStreams,
            "shrink-offset" => MutationKind::ShrinkOffset,
            other => bail!("unknown mutation class `{other}` — {usage}"),
        };
        let seed: u64 = flag(args, "--seed").map(|s| s.parse()).transpose()?.unwrap_or(1);
        let mut rng = Pcg32::new(seed);
        let m = mutate(&tape, &arena, kind, &mut rng).with_context(|| {
            format!("no {} mutation breaks this plan (try another seed or model)", kind.name())
        })?;
        println!("injected {}: {}", m.kind.name(), m.description);
        tape = m.tape;
        arena = m.arena;
    }
    let report = verify_with_arena(&tape, &arena);
    println!("{model} (batch {batch}{}):", if single { ", single-stream" } else { "" });
    print!("{}", report.render());
    if let Some(path) = flag(args, "--json") {
        std::fs::write(&path, report.to_json())?;
        println!("(JSON report written to {path})");
    }
    if flag(args, "--inject").is_some() {
        anyhow::ensure!(
            !report.is_clean(),
            "verifier MISSED the injected mutation — this is a verifier bug"
        );
        println!("verifier caught the injected mutation ✓");
        return Ok(());
    }
    anyhow::ensure!(report.is_clean(), "static plan verification failed");
    Ok(())
}

fn cmd_sim(model: &str, system: &str) -> Result<()> {
    let b = match system.to_lowercase().as_str() {
        "pytorch" => Baseline::PyTorch,
        "torchscript" => Baseline::TorchScript,
        "caffe2" => Baseline::Caffe2,
        "tensorflow" => Baseline::TensorFlow,
        "tensorrt" => Baseline::TensorRT,
        "tvm" => Baseline::Tvm,
        "nimble" => Baseline::Nimble,
        "nimble1" | "nimble-single" => Baseline::NimbleSingleStream,
        "schedmin" => Baseline::SchedMinimized,
        other => bail!("unknown system `{other}`"),
    };
    let g = models::build(model, 1);
    let prepared = nimble::baselines::prepare(&g, b, &GpuSpec::v100(), true);
    let r = nimble::baselines::run_prepared(&prepared, &GpuSpec::v100());
    println!(
        "{model} under {}: latency={} host={} gpu_active={} ({:.0}% active)",
        b.name(),
        fmt_secs(r.total_s),
        fmt_secs(r.host_s),
        fmt_secs(r.gpu_active_s),
        r.active_ratio() * 100.0
    );
    // optional Chrome-trace dump: nimble sim <model> <system> --trace out.json
    let args: Vec<String> = std::env::args().collect();
    if let Some(path) = flag(&args, "--trace") {
        let trace = nimble::sim::trace::to_chrome_trace(&r, |n| {
            prepared.graph.node(n).name.clone()
        });
        std::fs::write(&path, trace)?;
        println!("chrome trace written to {path} (open in chrome://tracing)");
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_infer(args: &[String]) -> Result<()> {
    use nimble::coordinator::{EngineConfig, ExecMode, NimbleEngine};
    use nimble::util::{Pcg32, Summary};

    let batch: usize = flag(args, "--batch").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let iters: usize = flag(args, "--iters").map(|s| s.parse()).transpose()?.unwrap_or(50);
    let mode = match flag(args, "--mode").as_deref() {
        Some("eager") => ExecMode::Eager,
        _ => ExecMode::Replay,
    };
    nimble::runtime::require_artifacts()?;
    let mut engine = NimbleEngine::build(EngineConfig { mode, ..Default::default() })?;
    let sched = engine.schedule(batch)?;
    println!(
        "engine built: {} tasks, {} streams, {} syncs, arena {} KiB (unshared {} KiB)",
        sched.n_tasks(),
        sched.n_streams,
        sched.n_events,
        sched.arena.arena_bytes / 1024,
        sched.arena.unshared_bytes() / 1024
    );
    let mut rng = Pcg32::new(7);
    let len: usize = sched.input_dims.iter().product();
    let input: Vec<f32> = (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
    let mut samples = Vec::with_capacity(iters);
    let mut out = Vec::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        out = match mode {
            ExecMode::Replay => engine.infer_prepared(batch, &input)?,
            ExecMode::Eager => engine.infer(batch, &input)?,
        };
        samples.push(t0.elapsed());
    }
    let s = Summary::from_durations(&samples);
    println!(
        "{:?} batch={batch} iters={iters}: p50={} p99={} mean={}",
        mode,
        fmt_secs(s.median()),
        fmt_secs(s.percentile(99.0)),
        fmt_secs(s.mean())
    );
    println!("logits[0][..4] = {:?}", &out[..4.min(out.len())]);
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_infer(_args: &[String]) -> Result<()> {
    bail!("`infer` needs the real PJRT runtime — rebuild with `--features xla` and run `make artifacts`")
}

/// `nimble serve`: drive the Runtime façade with Poisson traffic. The
/// PJRT artifact registry serves when built with `--features xla`
/// (`--mode replay|eager`); otherwise the tape-backed model zoo serves
/// on per-bucket lanes (`--model`, default mini_inception).
fn cmd_serve(args: &[String]) -> Result<()> {
    use nimble::serving::{InferRequest, Runtime};
    use nimble::util::Pcg32;
    use std::time::Duration;

    let n: usize = flag(args, "--requests").map(|s| s.parse()).transpose()?.unwrap_or(200);
    let rate: f64 = flag(args, "--rate").map(|s| s.parse()).transpose()?.unwrap_or(200.0);
    let deadline_ms: Option<u64> =
        flag(args, "--deadline-ms").map(|s| s.parse()).transpose()?;

    #[cfg(feature = "xla")]
    let server = {
        use nimble::coordinator::{EngineConfig, ExecMode};
        let mode = match flag(args, "--mode").as_deref() {
            Some("eager") => ExecMode::Eager,
            _ => ExecMode::Replay,
        };
        nimble::runtime::require_artifacts()?;
        println!("starting PJRT server (mode {mode:?}, {n} requests @ {rate} rps)...");
        Runtime::builder()
            .artifacts(EngineConfig { mode, ..Default::default() })
            .single_thread()
            .max_wait(Duration::from_millis(2))
            .build()?
    };
    #[cfg(not(feature = "xla"))]
    let server = {
        let model = flag(args, "--model").unwrap_or_else(|| "mini_inception".to_string());
        println!("starting tape-backed lane server ({model}, {n} requests @ {rate} rps)...");
        Runtime::builder()
            .model(&model)
            .buckets(&[1, 8])
            .max_wait(Duration::from_millis(2))
            .build()?
    };

    let len = server.example_len();
    let mut rng = Pcg32::new(1);
    let mut pending = Vec::with_capacity(n);
    for _ in 0..n {
        let input: Vec<f32> = (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
        let mut req = InferRequest::new(input);
        if let Some(ms) = deadline_ms {
            req = req.deadline_in(Duration::from_millis(ms));
        }
        pending.push(server.submit(req)?);
        std::thread::sleep(Duration::from_secs_f64(rng.gen_exp(rate)));
    }
    let mut shed = 0usize;
    for ticket in pending {
        use nimble::serving::InferOutcome;
        match ticket.outcome().context("response lost")? {
            InferOutcome::Output(_) => {}
            InferOutcome::DeadlineShed => shed += 1,
            InferOutcome::Failed(e) => return Err(anyhow::anyhow!(e)),
        }
    }
    let report = server.shutdown()?;
    if shed > 0 {
        println!("({shed} requests shed past their {} ms deadline)", deadline_ms.unwrap_or(0));
    }
    println!("{}", report.render());
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_train(args: &[String]) -> Result<()> {
    let steps: usize = flag(args, "--steps").map(|s| s.parse()).transpose()?.unwrap_or(300);
    nimble::runtime::require_artifacts()?;
    let report = nimble::training::run_training(steps, 20)?;
    println!("{}", report.render());
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_train(_args: &[String]) -> Result<()> {
    bail!("`train` needs the real PJRT runtime — rebuild with `--features xla` and run `make artifacts`")
}

fn cmd_cluster(args: &[String]) -> Result<()> {
    use nimble::aot::ReplayTape;
    use nimble::cluster::Cluster;
    use nimble::serving::{InferOutcome, InferRequest};
    use nimble::sim::{kernel_cost, simulate_cluster, ClusterSimPolicy, ClusterTraffic, HostProfile};
    use nimble::stream::rewrite::rewrite;
    use nimble::util::Pcg32;
    use std::time::Duration;

    let replicas: usize = flag(args, "--replicas").map(|s| s.parse()).transpose()?.unwrap_or(2);
    let n: usize = flag(args, "--requests").map(|s| s.parse()).transpose()?.unwrap_or(200);
    let rate: f64 = flag(args, "--rate").map(|s| s.parse()).transpose()?.unwrap_or(400.0);
    let deadline_ms: Option<u64> = flag(args, "--deadline-ms").map(|s| s.parse()).transpose()?;
    let drain_at: Option<usize> = flag(args, "--drain").map(|s| s.parse()).transpose()?;
    let round_robin = args.iter().any(|a| a == "--round-robin");
    let model = flag(args, "--model").unwrap_or_else(|| "mini_inception".to_string());

    let policy = if round_robin { "round-robin" } else { "p2c" };
    println!(
        "starting {replicas}-replica cluster ({model}, {policy} router, {n} requests @ {rate} rps)..."
    );
    let mut builder = Cluster::builder()
        .model(&model)
        .buckets(&[1, 8])
        .replicas(replicas)
        .max_wait(Duration::from_millis(2));
    builder = if round_robin { builder.route_round_robin() } else { builder.route_p2c(1) };
    let cluster = builder.build()?;

    let len = cluster.example_len();
    let mut rng = Pcg32::new(1);
    let start = Instant::now();
    let mut arrivals: Vec<(f64, f64)> = Vec::with_capacity(n);
    let mut pending = Vec::with_capacity(n);
    for i in 0..n {
        if drain_at.is_some() && i == n / 2 {
            let idx = drain_at.unwrap();
            println!("draining replica {idx} mid-run (traffic reroutes to survivors)...");
            let rep = cluster.drain_replica(idx)?;
            println!(
                "replica {idx} drained: completed={} shed={} failed={}",
                rep.n_requests, rep.deadline_shed, rep.failed
            );
        }
        let input: Vec<f32> = (0..len).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
        let mut req = InferRequest::new(input);
        let at = start.elapsed().as_secs_f64();
        let deadline_s = match deadline_ms {
            Some(ms) => {
                req = req.deadline_in(Duration::from_millis(ms));
                at + ms as f64 / 1e3
            }
            None => f64::INFINITY,
        };
        arrivals.push((at, deadline_s));
        pending.push(cluster.submit(req)?);
        std::thread::sleep(Duration::from_secs_f64(rng.gen_exp(rate)));
    }
    let (mut done, mut shed, mut failed) = (0usize, 0usize, 0usize);
    for ticket in pending {
        match ticket.outcome().context("response lost")? {
            InferOutcome::Output(_) => done += 1,
            InferOutcome::DeadlineShed => shed += 1,
            InferOutcome::Failed(_) => failed += 1,
        }
    }
    let report = cluster.shutdown()?;
    println!("{}", report.render());
    println!("client view: completed={done} shed={shed} failed={failed}");

    // The cluster DES's prediction for the same arrival tape (no
    // mid-run drains in the sim — skip the comparison when draining).
    if drain_at.is_none() {
        let g = models::build(&model, 1);
        let dev = GpuSpec::v100();
        let costs: Vec<_> = (0..g.n_nodes()).map(|v| kernel_cost(g.node(v), &dev)).collect();
        let tape = ReplayTape::for_op_graph(&g, &rewrite(&g, MatchingAlgo::HopcroftKarp), 4096);
        let sim = simulate_cluster(
            &ClusterTraffic { tape: &tape, costs: &costs, requests: &arrivals },
            HostProfile::nimble(),
            dev,
            &ClusterSimPolicy {
                replicas,
                lanes_per_replica: 1,
                p2c: !round_robin,
                seed: 1,
                closed_loop: false,
            },
        );
        println!(
            "DES prediction (open loop, batch-1 queue model): completed={} shed={} ({:.1}% shed rate) admitted={:?}",
            sim.completed(),
            sim.shed(),
            sim.shed_rate() * 100.0,
            sim.admitted_per_replica()
        );
    }
    Ok(())
}
