//! Minimal JSON helpers for a crates.io-free build: proper string
//! escaping (shared by the sim trace exporter and the telemetry flight
//! recorder) and a small recursive-descent parser used by trace
//! round-trip tests, `CostProfile::from_json`, and the measured-vs-
//! predicted diff in `nimble trace`.
//!
//! The parser accepts the JSON this crate emits (objects, arrays,
//! strings with `\uXXXX` escapes, finite numbers, booleans, null). It
//! is not a streaming parser and keeps the whole document in memory —
//! fine for trace files and bench reports, not meant for anything else.

use std::collections::BTreeMap;

/// Escape `s` as the *contents* of a JSON string literal (no
/// surrounding quotes): `"` and `\` are backslash-escaped, control
/// characters become `\n`/`\r`/`\t` or `\u00XX`.
pub fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    push_escaped(&mut out, s);
    out
}

/// Append the escaped form of `s` to `out` (allocation-free when `out`
/// has capacity).
pub fn push_escaped(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

/// A parsed JSON value. Object keys keep first-wins semantics and are
/// stored sorted (BTreeMap) — insertion order is not preserved, which
/// is fine for the schema-checked documents this crate reads back.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse a complete JSON document; trailing whitespace is allowed,
/// trailing garbage is an error.
pub fn parse_json(s: &str) -> Result<JsonValue, String> {
    let bytes = s.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing data at byte {pos}"));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected '{}' at byte {}", c as char, *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<JsonValue, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'{') => parse_object(b, pos),
        Some(b'[') => parse_array(b, pos),
        Some(b'"') => parse_string(b, pos).map(JsonValue::Str),
        Some(b't') => parse_lit(b, pos, "true", JsonValue::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", JsonValue::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", JsonValue::Null),
        Some(_) => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: JsonValue) -> Result<JsonValue, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("invalid literal at byte {}", *pos))
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<JsonValue, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    let n: f64 =
        text.parse().map_err(|_| format!("invalid number {text:?} at byte {start}"))?;
    if !n.is_finite() {
        return Err(format!("non-finite number at byte {start}"));
    }
    Ok(JsonValue::Num(n))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        let Some(&c) = b.get(*pos) else {
            return Err("unterminated string".into());
        };
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&esc) = b.get(*pos) else {
                    return Err("unterminated escape".into());
                };
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 > b.len() {
                            return Err("truncated \\u escape".into());
                        }
                        let hex = std::str::from_utf8(&b[*pos..*pos + 4])
                            .map_err(|e| e.to_string())?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| format!("bad \\u escape {hex:?}"))?;
                        *pos += 4;
                        // Surrogate pairs are not emitted by this crate;
                        // map lone surrogates to U+FFFD rather than erroring.
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(format!("bad escape '\\{}'", esc as char)),
                }
            }
            _ => {
                // Re-decode multi-byte UTF-8 sequences from the source.
                let w = utf8_width(c);
                if w == 1 {
                    out.push(c as char);
                } else {
                    let start = *pos - 1;
                    let end = start + w;
                    if end > b.len() {
                        return Err("truncated UTF-8 sequence".into());
                    }
                    let s = std::str::from_utf8(&b[start..end])
                        .map_err(|e| e.to_string())?;
                    out.push_str(s);
                    *pos = end;
                }
            }
        }
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_array(b: &[u8], pos: &mut usize) -> Result<JsonValue, String> {
    expect(b, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(JsonValue::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => {
                *pos += 1;
            }
            Some(b']') => {
                *pos += 1;
                return Ok(JsonValue::Arr(items));
            }
            _ => return Err(format!("expected ',' or ']' at byte {}", *pos)),
        }
    }
}

fn parse_object(b: &[u8], pos: &mut usize) -> Result<JsonValue, String> {
    expect(b, pos, b'{')?;
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(JsonValue::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        expect(b, pos, b':')?;
        let val = parse_value(b, pos)?;
        map.entry(key).or_insert(val);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => {
                *pos += 1;
            }
            Some(b'}') => {
                *pos += 1;
                return Ok(JsonValue::Obj(map));
            }
            _ => return Err(format!("expected ',' or '}}' at byte {}", *pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escaping_round_trips_hostile_labels() {
        let hostile = "op\"quote\\back\nnew\tta\u{1}b_μ";
        let doc = format!("{{\"name\": \"{}\"}}", escape_json(hostile));
        let v = parse_json(&doc).expect("escaped doc must parse");
        assert_eq!(v.get("name").and_then(|n| n.as_str()), Some(hostile));
    }

    #[test]
    fn parses_nested_documents() {
        let v = parse_json(
            r#"{"a": [1, 2.5, -3e-2], "b": {"c": true, "d": null}, "e": "x"}"#,
        )
        .unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_array().unwrap()[0].as_u64(), Some(1));
        assert_eq!(v.get("b").unwrap().get("c"), Some(&JsonValue::Bool(true)));
        assert_eq!(v.get("b").unwrap().get("d"), Some(&JsonValue::Null));
        assert_eq!(v.get("e").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_json("{\"a\": }").is_err());
        assert!(parse_json("[1, 2,]").is_err());
        assert!(parse_json("[1] trailing").is_err());
        assert!(parse_json("\"unterminated").is_err());
    }
}
