//! Small self-contained utilities: PRNG, statistics, property-test runner,
//! table formatting. The build is fully offline (no crates.io), so these
//! replace `rand`, `criterion`'s stats, and `proptest`.

pub mod json;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod table;

pub use prng::Pcg32;
pub use stats::Summary;
