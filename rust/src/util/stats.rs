//! Timing statistics for the bench harness and serving metrics
//! (offline replacement for the parts of criterion/hdrhistogram we need).

use std::time::Duration;

/// Summary statistics over a set of samples (stored in seconds).
#[derive(Debug, Clone)]
pub struct Summary {
    sorted: Vec<f64>,
    mean: f64,
}

impl Summary {
    /// Build a summary from raw `f64` samples (any unit; caller's choice).
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty(), "Summary needs at least one sample");
        samples.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        Summary { sorted: samples, mean }
    }

    /// Build a summary from `Duration` samples; values are seconds.
    pub fn from_durations(durations: &[Duration]) -> Self {
        Self::from_samples(durations.iter().map(|d| d.as_secs_f64()).collect())
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    /// The raw sorted samples (in the caller's unit) — lets
    /// aggregators merge summaries losslessly instead of mixing
    /// percentiles (the cluster report fold uses this).
    pub fn samples(&self) -> &[f64] {
        &self.sorted
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn min(&self) -> f64 {
        self.sorted[0]
    }

    pub fn max(&self) -> f64 {
        *self.sorted.last().unwrap()
    }

    /// Percentile in `[0, 100]` with linear interpolation between samples.
    pub fn percentile(&self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p));
        if self.sorted.len() == 1 {
            return self.sorted[0];
        }
        let rank = p / 100.0 * (self.sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.sorted[lo] * (1.0 - frac) + self.sorted[hi] * frac
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        if self.sorted.len() < 2 {
            return 0.0;
        }
        let var = self
            .sorted
            .iter()
            .map(|x| (x - self.mean) * (x - self.mean))
            .sum::<f64>()
            / (self.sorted.len() - 1) as f64;
        var.sqrt()
    }
}

/// Format a duration given in seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_of_known_set() {
        let s = Summary::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let s = Summary::from_samples(vec![0.0, 10.0]);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 10.0);
        assert!((s.percentile(50.0) - 5.0).abs() < 1e-12);
        assert!((s.percentile(99.0) - 9.9).abs() < 1e-9);
    }

    #[test]
    fn stddev_of_constant_is_zero() {
        let s = Summary::from_samples(vec![2.0; 8]);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn single_sample_is_its_own_everything() {
        let s = Summary::from_samples(vec![7.5]);
        assert_eq!(s.percentile(99.0), 7.5);
        assert_eq!(s.mean(), 7.5);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn unsorted_input_is_handled() {
        let s = Summary::from_samples(vec![5.0, 1.0, 3.0]);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_secs(2.0).ends_with(" s"));
        assert!(fmt_secs(2e-3).ends_with("ms"));
        assert!(fmt_secs(2e-6).ends_with("µs"));
        assert!(fmt_secs(2e-9).ends_with("ns"));
    }
}
