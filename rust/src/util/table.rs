//! Plain-text table rendering for the figure harness and bench reports,
//! plus TSV output for machine consumption (results/ directory).

use std::fmt::Write as _;
use std::io;
use std::path::Path;

/// A simple column-aligned text table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let pad = widths[i] - c.chars().count();
                let _ = write!(out, "{}{}", c, " ".repeat(pad));
                if i + 1 < ncols {
                    let _ = write!(out, "  ");
                }
            }
            let _ = writeln!(out);
        };
        fmt_row(&mut out, &self.header);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            fmt_row(&mut out, row);
        }
        out
    }

    /// Write the table as TSV (header + rows).
    pub fn write_tsv(&self, path: &Path) -> io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut s = String::new();
        let _ = writeln!(s, "{}", self.header.join("\t"));
        for row in &self.rows {
            let _ = writeln!(s, "{}", row.join("\t"));
        }
        std::fs::write(path, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_columns() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["a", "1"]).row(vec!["longer", "22"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("a     "));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn mismatched_row_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn tsv_round_trip() {
        let mut t = Table::new(vec!["x", "y"]);
        t.row(vec!["1", "2"]);
        let dir = std::env::temp_dir().join("nimble_table_test");
        let p = dir.join("t.tsv");
        t.write_tsv(&p).unwrap();
        let content = std::fs::read_to_string(&p).unwrap();
        assert_eq!(content, "x\ty\n1\t2\n");
        let _ = std::fs::remove_dir_all(dir);
    }
}
