//! Deterministic PRNG (PCG32 seeded through SplitMix64).
//!
//! Used by the random-DAG generator, the property-test runner, the workload
//! generators and the serving benchmarks. Determinism matters: every failing
//! property test prints the seed that reproduces it.

/// PCG-XSH-RR 32-bit generator (O'Neill 2014).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

/// SplitMix64 step — used to expand a single `u64` seed into PCG state.
fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Pcg32 {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let state = splitmix64(&mut s);
        let inc = splitmix64(&mut s) | 1;
        let mut rng = Pcg32 { state: 0, inc };
        rng.state = state.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform integer in `[0, bound)` (Lemire's rejection method, simplified).
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range bound must be > 0");
        // 64-bit multiply-shift is unbiased enough for test workloads and
        // avoids the modulo bias of naive `% bound`.
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn gen_range_inclusive(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.gen_range(hi - lo + 1)
    }

    /// Uniform `f64` in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Uniform `f32` in `[lo, hi)`.
    pub fn gen_f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.gen_f64() as f32) * (hi - lo)
    }

    /// Sample from an exponential distribution with rate `lambda` (mean 1/λ).
    /// Used by the serving workload generator for Poisson arrivals.
    pub fn gen_exp(&mut self, lambda: f64) -> f64 {
        let u = 1.0 - self.gen_f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose a uniformly random element (panics on empty slice).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut rng = Pcg32::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut rng = Pcg32::new(3);
        for _ in 0..1000 {
            let v = rng.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gen_bool_probability_roughly_matches() {
        let mut rng = Pcg32::new(11);
        let hits = (0..10_000).filter(|_| rng.gen_bool(0.3)).count();
        assert!((2700..3300).contains(&hits), "hits={hits}");
    }

    #[test]
    fn exp_mean_close_to_inverse_rate() {
        let mut rng = Pcg32::new(5);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.gen_exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg32::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
