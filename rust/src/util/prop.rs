//! Minimal property-test runner (offline replacement for `proptest`).
//!
//! A property is a function `Fn(&mut Pcg32) -> Result<(), String>` that draws
//! arbitrary inputs from the PRNG and returns `Err(reason)` on violation. The
//! runner executes `cases` iterations with derived seeds; on failure it panics
//! with the *case seed*, so `check_seed` reproduces the exact failing input.

use super::prng::Pcg32;

/// Run `cases` random cases of `prop`, panicking with the failing seed.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Pcg32) -> Result<(), String>,
{
    check_from(name, 0xC0FFEE, cases, prop)
}

/// Like [`check`] but with an explicit base seed.
pub fn check_from<F>(name: &str, base_seed: u64, cases: u64, prop: F)
where
    F: Fn(&mut Pcg32) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Pcg32::new(seed);
        if let Err(reason) = prop(&mut rng) {
            panic!(
                "property `{name}` failed on case {case} (seed {seed:#x}):\n  {reason}\n\
                 reproduce with util::prop::check_seed(\"{name}\", {seed:#x}, prop)"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn check_seed<F>(name: &str, seed: u64, prop: F)
where
    F: Fn(&mut Pcg32) -> Result<(), String>,
{
    let mut rng = Pcg32::new(seed);
    if let Err(reason) = prop(&mut rng) {
        panic!("property `{name}` failed (seed {seed:#x}): {reason}");
    }
}

/// Helper: assert-like macro-free equality check inside properties.
pub fn ensure(cond: bool, msg: impl FnOnce() -> String) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = std::cell::Cell::new(0u64);
        let counter = &mut count;
        check("always-true", 50, |rng| {
            counter.set(counter.get() + 1);
            let _ = rng.next_u32();
            Ok(())
        });
        assert_eq!(count.get(), 50);
    }

    #[test]
    #[should_panic(expected = "property `always-false` failed")]
    fn failing_property_panics_with_name() {
        check("always-false", 10, |_| Err("nope".to_string()));
    }

    #[test]
    fn ensure_helper() {
        assert!(ensure(true, || "x".into()).is_ok());
        assert_eq!(ensure(false, || "boom".into()), Err("boom".to_string()));
    }

    #[test]
    fn seeds_differ_across_cases() {
        // If all cases used the same seed this property would trivially pass
        // with identical draws; verify we actually see diversity.
        let seen = std::cell::RefCell::new(std::collections::HashSet::new());
        check("seed-diversity", 20, |rng| {
            seen.borrow_mut().insert(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen.borrow().len(), 20);
    }
}
