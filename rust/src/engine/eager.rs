//! Eager run-time scheduler — the PyTorch-shaped baseline the AoT schedule
//! is measured against (paper §2's scheduling-procedure walkthrough,
//! implemented for real):
//!
//!   select operator → check input types/shapes → calculate output shape →
//!   dispatch the kernel by (op, dtype, shape) key → allocate the output
//!   from the caching pool → prepare function arguments → submit.
//!
//! Every step does real work on real data structures per request; only the
//! GPU tasks themselves are shared with the replay path (same compiled
//! executables), exactly like the paper's Fig. 2b methodology.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::Arc;

use super::alloc::CachingAllocator;
use crate::runtime::manifest::{InputRef, NodeEntry};
use crate::runtime::ArtifactRegistry;

/// Per-request scheduling statistics (for the overhead report).
#[derive(Debug, Clone, Copy, Default)]
pub struct EagerStats {
    pub n_ops: usize,
    pub n_dispatch_lookups: usize,
    pub n_allocs: usize,
    pub arena_high_water: u64,
    /// Wall time spent in the scheduling procedure itself (shape checks,
    /// dispatch, allocation, marshalling) — the paper's "scheduling
    /// overhead", excluding kernel execution.
    pub sched_s: f64,
}

pub struct EagerEngine {
    registry: Arc<ArtifactRegistry>,
    batch: usize,
    nodes: Vec<NodeEntry>,
    /// dispatch table keyed by (artifact, out-dims) — rebuilt lookups per op
    /// per request, like a framework's kernel registry.
    dispatch: HashMap<String, Arc<xla::PjRtLoadedExecutable>>,
    input_dims: Vec<usize>,
    /// uses per node output (for allocator free bookkeeping).
    n_uses: HashMap<String, usize>,
}

fn dispatch_key(artifact: &str, dims: &[usize]) -> String {
    let mut key = String::with_capacity(artifact.len() + 4 * dims.len() + 8);
    key.push_str(artifact);
    key.push_str(":f32:");
    for d in dims {
        key.push_str(&d.to_string());
        key.push('x');
    }
    key
}

impl EagerEngine {
    pub fn new(registry: Arc<ArtifactRegistry>, batch: usize) -> Result<Self> {
        let nodes = registry
            .manifest
            .graphs
            .get(&batch)
            .with_context(|| format!("no node graph for batch {batch}"))?
            .clone();
        let mut dispatch = HashMap::new();
        for n in &nodes {
            dispatch.insert(dispatch_key(&n.artifact, &n.dims), registry.executable(&n.artifact)?);
        }
        let mut n_uses: HashMap<String, usize> = HashMap::new();
        for n in &nodes {
            for i in &n.inputs {
                if let InputRef::Node(d) = i {
                    *n_uses.entry(d.clone()).or_default() += 1;
                }
            }
        }
        let input_dims = registry
            .manifest
            .inputs
            .get(&batch)
            .cloned()
            .with_context(|| format!("no input dims for batch {batch}"))?;
        Ok(EagerEngine { registry, batch, nodes, dispatch, input_dims, n_uses })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn input_len(&self) -> usize {
        self.input_dims.iter().product()
    }

    /// Run one inference, performing the full scheduling procedure per op.
    pub fn infer(&self, input: &[f32]) -> Result<(Vec<f32>, EagerStats)> {
        let client = &self.registry.client;
        if input.len() != self.input_len() {
            bail!("input length {} != {}", input.len(), self.input_len());
        }
        let mut stats = EagerStats::default();
        let mut allocator = CachingAllocator::new();
        let mut vals: HashMap<&str, (xla::PjRtBuffer, Vec<usize>, super::alloc::Block)> =
            HashMap::with_capacity(self.nodes.len() + 1);
        let input_block = allocator.allocate(4 * input.len() as u64);
        let input_buf = client.buffer_f32(input, &self.input_dims)?;
        vals.insert("input", (input_buf, self.input_dims.clone(), input_block));
        let mut remaining_uses: HashMap<&str, usize> =
            self.n_uses.iter().map(|(k, v)| (k.as_str(), *v)).collect();

        let last = self.nodes.last().context("empty graph")?.name.clone();
        for n in &self.nodes {
            stats.n_ops += 1;
            let sched_t0 = std::time::Instant::now();
            // 1. type/shape check of every input (the run-time "check the
            //    types and shapes of input tensors" step).
            let mut arg_dims: Vec<&[usize]> = Vec::with_capacity(n.inputs.len());
            for i in &n.inputs {
                match i {
                    InputRef::Node(d) => {
                        let (_, dims, _) =
                            vals.get(d.as_str()).with_context(|| format!("missing {d}"))?;
                        arg_dims.push(dims);
                    }
                    InputRef::Weight(w) => {
                        let (_, dims) = &self.registry.manifest.weights[w];
                        arg_dims.push(dims);
                    }
                }
            }
            // 2. calculate output shape (validated against the manifest the
            //    way a framework's shape functions recompute it).
            let out_dims = n.dims.clone();
            let out_bytes = 4 * out_dims.iter().product::<usize>() as u64;
            debug_assert!(!arg_dims.is_empty());
            // 3. kernel dispatch by string key.
            let key = dispatch_key(&n.artifact, &out_dims);
            stats.n_dispatch_lookups += 1;
            let exe = self
                .dispatch
                .get(&key)
                .with_context(|| format!("dispatch miss for {key}"))?
                .clone();
            // 4. output allocation from the caching pool.
            let out_block = allocator.allocate(out_bytes);
            stats.n_allocs += 1;
            // 5. argument marshalling.
            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(n.inputs.len());
            for i in &n.inputs {
                match i {
                    InputRef::Node(d) => args.push(&vals[d.as_str()].0),
                    InputRef::Weight(w) => args.push(self.registry.weight_ref(w)?),
                }
            }
            stats.sched_s += sched_t0.elapsed().as_secs_f64();
            // 6. submit.
            let mut out = exe.execute_b(&args)?;
            let buf = out.remove(0).remove(0);
            vals.insert(n.name.as_str(), (buf, out_dims, out_block));
            // free dead inputs back to the cached pool
            for i in &n.inputs {
                if let InputRef::Node(d) = i {
                    if let Some(uses) = remaining_uses.get_mut(d.as_str()) {
                        *uses -= 1;
                        if *uses == 0 && d != &last {
                            if let Some((_, _, block)) = vals.get(d.as_str()) {
                                allocator.free(*block);
                            }
                        }
                    }
                }
            }
        }
        stats.arena_high_water = allocator.high_water_bytes();
        let (out_buf, _, _) = vals.remove(last.as_str()).context("no output")?;
        let host = client.to_host_f32(&out_buf)?;
        Ok((host, stats))
    }
}
