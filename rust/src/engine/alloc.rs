//! Caching allocator bookkeeping — the paper's §2 scheduling step
//! "allocate GPU memory for the output tensors … typically by retrieving
//! memory blocks from the cached pool of GPU memory".
//!
//! PyTorch's CUDA caching allocator rounds sizes, searches a free-list per
//! size class, and splits/caches blocks. The eager engine performs this
//! bookkeeping on every operator execution (the real host-side cost the
//! paper measures); the AoT scheduler runs it once during the pre-run and
//! reserves the blocks for replay.

use std::collections::BTreeMap;

/// Block ticket returned by `allocate`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Block {
    pub offset: u64,
    pub size: u64,
}

/// A simplified CUDA-caching-allocator: power-of-two-ish rounding, per-size
/// free lists, high-water-mark arena.
#[derive(Debug, Default)]
pub struct CachingAllocator {
    /// size → free offsets (cached blocks).
    free: BTreeMap<u64, Vec<u64>>,
    /// bump pointer for fresh blocks.
    high_water: u64,
    /// live bytes (for stats / leak detection).
    live: u64,
    n_allocs: u64,
    n_cache_hits: u64,
}

/// Round like the CUDA caching allocator: 512-byte quantum below 1 MiB,
/// 2 MiB quantum above.
pub fn round_size(bytes: u64) -> u64 {
    const SMALL_Q: u64 = 512;
    const BIG_Q: u64 = 2 * 1024 * 1024;
    if bytes == 0 {
        return SMALL_Q;
    }
    if bytes < 1024 * 1024 {
        bytes.div_ceil(SMALL_Q) * SMALL_Q
    } else {
        bytes.div_ceil(BIG_Q) * BIG_Q
    }
}

impl CachingAllocator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate a block (free-list hit or fresh arena extension).
    pub fn allocate(&mut self, bytes: u64) -> Block {
        let size = round_size(bytes);
        self.n_allocs += 1;
        self.live += size;
        if let Some(list) = self.free.get_mut(&size) {
            if let Some(offset) = list.pop() {
                self.n_cache_hits += 1;
                return Block { offset, size };
            }
        }
        let offset = self.high_water;
        self.high_water += size;
        Block { offset, size }
    }

    /// Return a block to the cache.
    pub fn free(&mut self, block: Block) {
        self.live = self.live.saturating_sub(block.size);
        self.free.entry(block.size).or_default().push(block.offset);
    }

    /// Total arena footprint ever reserved.
    pub fn high_water_bytes(&self) -> u64 {
        self.high_water
    }

    pub fn live_bytes(&self) -> u64 {
        self.live
    }

    pub fn cache_hit_rate(&self) -> f64 {
        if self.n_allocs == 0 {
            0.0
        } else {
            self.n_cache_hits as f64 / self.n_allocs as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rounding_quanta() {
        assert_eq!(round_size(0), 512);
        assert_eq!(round_size(1), 512);
        assert_eq!(round_size(512), 512);
        assert_eq!(round_size(513), 1024);
        assert_eq!(round_size(2 * 1024 * 1024 + 1), 4 * 1024 * 1024);
    }

    #[test]
    fn free_then_allocate_hits_cache() {
        let mut a = CachingAllocator::new();
        let b1 = a.allocate(1000);
        a.free(b1);
        let b2 = a.allocate(900); // same 1024-byte class
        assert_eq!(b1.offset, b2.offset);
        assert!(a.cache_hit_rate() > 0.0);
    }

    #[test]
    fn distinct_live_blocks_never_overlap() {
        let mut a = CachingAllocator::new();
        let blocks: Vec<Block> = (0..50).map(|i| a.allocate(100 * (i + 1))).collect();
        for (i, x) in blocks.iter().enumerate() {
            for y in &blocks[i + 1..] {
                let disjoint = x.offset + x.size <= y.offset || y.offset + y.size <= x.offset;
                assert!(disjoint, "{x:?} overlaps {y:?}");
            }
        }
    }

    #[test]
    fn steady_state_reuses_arena() {
        // Repeated identical iteration (the static-network pattern) must not
        // grow the arena after the first pass.
        let mut a = CachingAllocator::new();
        let sizes = [4096u64, 128, 65536, 4096];
        let mut first_high = 0;
        for iter in 0..10 {
            let blocks: Vec<Block> = sizes.iter().map(|&s| a.allocate(s)).collect();
            for b in blocks {
                a.free(b);
            }
            if iter == 0 {
                first_high = a.high_water_bytes();
            }
        }
        assert_eq!(a.high_water_bytes(), first_high);
        assert_eq!(a.live_bytes(), 0);
    }
}
