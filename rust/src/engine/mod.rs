//! Real execution engines over the XLA/PJRT runtime.
//!
//! * [`eager`] — the run-time scheduling baseline: every request pays the
//!   full per-operator scheduling procedure of the paper's §2 (shape
//!   check, dispatch lookup, caching-allocator bookkeeping, argument
//!   marshalling) before each task submission.
//! * AoT replay lives in [`crate::aot::schedule`]: the same executables,
//!   pre-resolved once; requests are raw submission loops.
//! * [`alloc`] — the caching-allocator bookkeeping both share.
//!
//! The measured eager-vs-replay gap on this substrate is the paper's
//! Fig. 2b experiment (run by `examples/quickstart.rs` and
//! `rust/benches/bench_overhead.rs`).

pub mod alloc;
pub mod eager;

pub use eager::EagerEngine;
