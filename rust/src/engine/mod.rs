//! Execution engines.
//!
//! * [`executor`] — the parallel multi-stream replay executor: a
//!   persistent per-stream worker pool driving a compiled
//!   [`ReplayTape`](crate::aot::tape::ReplayTape) through a preallocated
//!   slot arena and event table with zero heap allocation per task. This
//!   is the paper's multi-stream replay engine on the virtual-GPU
//!   substrate, and the engine behind the non-PJRT serving path.
//! * [`eager`] (feature `xla`) — the run-time scheduling baseline over
//!   real XLA/PJRT executables: every request pays the full per-operator
//!   scheduling procedure of the paper's §2 (shape check, dispatch
//!   lookup, caching-allocator bookkeeping, argument marshalling) before
//!   each task submission.
//! * [`alloc`] — the caching-allocator bookkeeping the eager baseline
//!   exercises.
//!
//! AoT replay over PJRT lives in [`crate::aot::schedule`]; the measured
//! eager-vs-replay gap is the paper's Fig. 2b experiment
//! (`rust/benches/bench_overhead.rs`).

pub mod alloc;
#[cfg(feature = "xla")]
pub mod eager;
pub mod executor;

#[cfg(feature = "xla")]
pub use eager::EagerEngine;
pub use executor::{
    EventTable, ExecOptions, ReplayContext, SharedWorkerPool, SyntheticKernel, TapeKernel,
};
