//! Parallel multi-stream replay executor with a zero-allocation hot path.
//!
//! This is the run-time half of the paper's claim: the AoT scheduler
//! already computed *what* to run (`ReplayTape`: per-stream tapes of
//! integer-resolved task records) — at request time there is nothing
//! left to decide. A [`ReplayContext`] owns:
//!
//! * a **slot arena** — ONE contiguous preallocated `f32` reservation
//!   for the whole tape, with per-slot `(offset, len)` views resolved at
//!   build from the stream-aware [`ArenaPlan`](crate::aot::memory::ArenaPlan):
//!   two slots share bytes only if the tape's happens-before order keeps
//!   them temporally disjoint in *every* legal execution
//!   ([`crate::aot::memory::happens_before_conflicts`]). Written in
//!   place on every replay (no per-request allocation); optionally drawn
//!   from a shared [`ArenaPool`] so serving lanes recycle reservations
//!   across context builds. In debug builds the plan's uncovered holes
//!   are seeded with canary words and re-checked after every replay, so
//!   a task writing outside its view is caught, not silently aliased,
//! * an **event table** — one atomic flag per cross-stream sync, with
//!   condvar parking (the `cudaStreamWaitEvent` pattern: record after
//!   the producer on its stream, wait before the consumer on its
//!   stream),
//! * a **persistent worker pool** — one worker per stream, parked
//!   between replays and released by an epoch handshake — or, with
//!   [`ExecOptions::max_workers`], a capped **work-sharing pool** where
//!   fewer workers cooperatively schedule all streams: a stream that
//!   would block on an unfired event parks (releasing its worker) and
//!   is re-queued by whichever worker records the event, so a serving
//!   deployment whose lanes multiply total stream count past the
//!   physical cores does not drown in idle threads — or, with
//!   [`ExecOptions::shared_pool`], a lease on ONE process-wide
//!   **work-stealing pool** ([`SharedWorkerPool`]) whose workers serve
//!   *every* leased context: a parked stream releases its worker back
//!   to the global pool (not to its own context), so elastic serving
//!   deployments can scale lanes × streams far past the cores while
//!   total worker threads stay capped at the pool size, and
//! * per-worker **scratch argument buffers** sized to the tape's widest
//!   task, reused across tasks.
//!
//! # Memory-safety argument
//!
//! The arena hands out `&[f32]` / `&mut [f32]` views through
//! `UnsafeCell`, so the borrow checker does not police slot aliasing;
//! the sync plan does. Tapes are compiled from launch plans whose sync
//! plans satisfy `stream::sync::plan_is_safe`: every dependency edge
//! (producer slot → consumer task) is realized by a path of same-stream
//! FIFO edges (program order inside one worker) and record→wait event
//! edges (release/acquire through [`EventTable`]). Therefore every slot
//! read *happens-after* the unique write of that slot, and the writer
//! holds the only live `&mut` — each slot is written by exactly one
//! record per replay. Views of **different** slots may overlap bytes,
//! but only when the arena plan proved the pair temporally disjoint
//! under that same happens-before order — so two live borrows never
//! overlap, and the bytes any read observes are exactly the producer's.
//! The differential tests in `tests/integration_executor.rs` and the
//! arena property in `tests/prop_harness.rs` check the resulting
//! bit-exactness on every zoo model and on random graphs, against both
//! the serial oracle and the unshared per-slot layout.
//!
//! # Zero-allocation accounting
//!
//! Every site on the per-task path that *could* allocate (scratch
//! growth — slot views are fixed slices, so they cannot) increments an
//! instrumented counter instead of being assumed away;
//! [`ReplayContext::alloc_events`] exposes it and a steady-state test
//! asserts it stays at zero.

use crate::aot::memory::{
    happens_before_conflicts, plan_respects_conflicts, plan_with_conflicts, ArenaLease, ArenaPlan,
    ArenaPool,
};
use crate::aot::tape::{ReplayTape, TapeArg, TapeOp, TapeRole};
use crate::fault::{FaultInjector, FaultPlan, OpFault, ReplayFault};
use crate::telemetry::{EventKind, Telemetry};
use std::any::Any;
use std::cell::UnsafeCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// A compute backend for tape tasks: reads resolved argument slices,
/// writes the output slice in place. Implementations must be
/// deterministic functions of `(op, args)` for the executor's
/// bit-exactness guarantee to hold.
pub trait TapeKernel: Send + Sync + 'static {
    fn execute(&self, op: &TapeOp, args: &[&[f32]], out: &mut [f32]);
}

/// Deterministic synthetic kernel for the virtual-GPU substrate: mixes
/// the argument values (order-sensitively) with a node-derived seed and
/// squashes to keep magnitudes bounded on deep graphs. Bit-identical
/// however tasks are interleaved, so any missed synchronization shows
/// up as a differential mismatch.
pub struct SyntheticKernel;

impl TapeKernel for SyntheticKernel {
    fn execute(&self, op: &TapeOp, args: &[&[f32]], out: &mut [f32]) {
        let seed = op.node as f32 * 0.618_034 + 1.0;
        for (i, o) in out.iter_mut().enumerate() {
            let mut acc = seed + i as f32 * 1e-3;
            for a in args {
                let v = if a.is_empty() { 0.0 } else { a[i % a.len()] };
                acc = acc * 0.731_25 + v;
            }
            *o = acc / (1.0 + acc.abs());
        }
    }
}

/// Event table: one flag per cross-stream synchronization. `record` is
/// a SeqCst flag store plus a wake only when someone is parked; `wait`
/// is an acquire fast-path with condvar parking and a hard deadline
/// (so an unsafe plan or dead worker turns into an error, never a
/// hang).
pub struct EventTable {
    flags: Vec<AtomicU32>,
    /// Parked (or about-to-park) waiter count; lets `record` skip the
    /// lock + notify entirely in the common nobody-is-waiting case.
    waiters: AtomicU32,
    lock: Mutex<()>,
    cv: Condvar,
    timeout: Duration,
}

impl EventTable {
    pub fn new(n_events: usize, timeout: Duration) -> EventTable {
        EventTable {
            flags: (0..n_events).map(|_| AtomicU32::new(0)).collect(),
            waiters: AtomicU32::new(0),
            lock: Mutex::new(()),
            cv: Condvar::new(),
            timeout,
        }
    }

    /// Clear all flags. Callers must ensure no worker is mid-replay; the
    /// pool's epoch handshake publishes the reset to the workers.
    pub fn reset(&self) {
        for f in &self.flags {
            f.store(0, Ordering::Relaxed);
        }
    }

    /// Fire event `e` (exactly once per replay, by its unique recorder).
    ///
    /// Missed-wakeup freedom: the flag store and the waiter-count
    /// accesses are all SeqCst, so in the single total order either the
    /// recorder sees the waiter's increment (and notifies), or the
    /// waiter's increment comes after the recorder's store — and then
    /// the waiter's flag check (made after incrementing, under the
    /// lock) observes the flag set and never parks.
    pub fn record(&self, e: usize) {
        self.flags[e].store(1, Ordering::SeqCst);
        if self.waiters.load(Ordering::SeqCst) != 0 {
            // Take and drop the lock so a parked waiter is either inside
            // `wait_timeout` (and gets the notify) or re-checks the flag
            // under the lock after us.
            drop(self.lock.lock().unwrap());
            self.cv.notify_all();
        }
    }

    /// Block until event `e` fires, or error out at the deadline.
    pub fn wait(&self, e: usize) -> Result<(), String> {
        if self.flags[e].load(Ordering::Acquire) != 0 {
            return Ok(());
        }
        let deadline = Instant::now() + self.timeout;
        let mut guard = self.lock.lock().unwrap();
        self.waiters.fetch_add(1, Ordering::SeqCst);
        let result = loop {
            if self.flags[e].load(Ordering::SeqCst) != 0 {
                break Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                break Err(format!(
                    "event {e} did not fire within {:?}: unsafe sync plan or failed worker",
                    self.timeout
                ));
            }
            let (g, _timeout) = self.cv.wait_timeout(guard, deadline - now).unwrap();
            guard = g;
        };
        self.waiters.fetch_sub(1, Ordering::SeqCst);
        result
    }

    pub fn n_events(&self) -> usize {
        self.flags.len()
    }

    /// Non-blocking check (the work-sharing pool parks streams instead
    /// of blocking a worker thread inside [`wait`](Self::wait)).
    pub fn is_set(&self, e: usize) -> bool {
        self.flags[e].load(Ordering::SeqCst) != 0
    }
}

/// Canary bit pattern seeding the arena's uncovered holes (any `u32` is
/// a valid `f32` bit pattern; this one is distinctive in a debugger).
const CANARY_BITS: u32 = 0xDEAD_F00D;

/// Guard elements appended past the arena's top, canary-seeded like the
/// holes — catches kernels running off the end of the last slot.
const GUARD_ELEMS: usize = 64;

/// Slot arena: one contiguous preallocated buffer for the whole tape,
/// with per-slot `(offset, len)` views resolved at build from the
/// [`ArenaPlan`]. Access is `unsafe` because exclusivity is guaranteed
/// by the verified sync plan plus the plan's conflict-disjointness, not
/// the borrow checker (see module docs). Bytes covered by no slot view
/// (packing holes, reservation slack, the tail guard) are seeded with
/// canary words; [`check_canaries`](Self::check_canaries) detects any
/// task that wrote outside its view.
struct SlotArena {
    /// Owns the backing buffer (sized once at build, never reallocated);
    /// replay-time access goes through `base`, never through the `Vec`,
    /// so concurrent disjoint views never materialize a borrow of the
    /// whole buffer.
    lease: UnsafeCell<ArenaLease>,
    /// Cached data pointer of the backing buffer.
    base: *mut f32,
    /// `(offset, len)` in elements, per slot.
    views: Vec<(usize, usize)>,
    /// Canary element ranges: plan holes + the tail guard.
    canaries: Vec<(usize, usize)>,
}

// SAFETY: concurrent access is coordinated by the sync plan (module
// docs); `base` points into the heap allocation `lease` owns, which is
// stable for the arena's lifetime.
unsafe impl Send for SlotArena {}
// SAFETY: shared references only hand out raw-pointer views whose
// exclusivity the verified sync plan guarantees; no interior `&`-based
// mutation happens outside those views.
unsafe impl Sync for SlotArena {}

impl SlotArena {
    fn new(lens: &[usize], plan: &ArenaPlan, mut lease: ArenaLease) -> SlotArena {
        debug_assert_eq!(plan.offsets.len(), lens.len());
        let arena_elems = (plan.arena_bytes / 4) as usize;
        // Byte offsets are allocator-rounded (512-byte quanta), so every
        // offset and hole boundary is element-aligned.
        let views: Vec<(usize, usize)> =
            lens.iter().enumerate().map(|(s, &l)| ((plan.offsets[s] / 4) as usize, l)).collect();
        let extents: Vec<u64> = lens.iter().map(|&l| 4 * l as u64).collect();
        let mut canaries: Vec<(usize, usize)> = plan
            .holes(&extents)
            .into_iter()
            .map(|(a, b)| ((a / 4) as usize, (b / 4) as usize))
            .collect();
        canaries.push((arena_elems, arena_elems + GUARD_ELEMS));
        lease.buf.clear();
        lease.buf.resize(arena_elems + GUARD_ELEMS, 0.0);
        let canary = f32::from_bits(CANARY_BITS);
        for &(a, b) in &canaries {
            for v in &mut lease.buf[a..b] {
                *v = canary;
            }
        }
        // Moving the lease moves only the Vec's header; the heap block
        // (and so this pointer) is stable until the lease drops.
        let base = lease.buf.as_mut_ptr();
        SlotArena { lease: UnsafeCell::new(lease), base, views, canaries }
    }

    /// # Safety
    /// Per the sync plan, the slot's writer happens-before this read,
    /// and no writer of bytes overlapping this view is live.
    unsafe fn get(&self, slot: usize) -> &[f32] {
        let (off, len) = self.views[slot];
        // SAFETY: `views` was resolved from the arena plan at build, so
        // `off + len` lies inside the buffer `base` points into (the
        // build asserts extents fit the reservation); exclusivity over
        // these bytes is the caller's contract above.
        unsafe { std::slice::from_raw_parts(self.base.add(off), len) }
    }

    /// # Safety
    /// Per the sync plan, this record is the unique live accessor of
    /// every byte in the view (its writer slot, before any reader may
    /// observe it).
    #[allow(clippy::mut_from_ref)]
    unsafe fn get_mut(&self, slot: usize) -> &mut [f32] {
        let (off, len) = self.views[slot];
        // SAFETY: in-bounds per the build-time arena plan (as in
        // `get`); uniqueness of this `&mut` is the caller's contract
        // above, so no aliasing reference exists while it lives.
        unsafe { std::slice::from_raw_parts_mut(self.base.add(off), len) }
    }

    /// Verify every canary word is intact. Callers must ensure no replay
    /// is in flight.
    fn check_canaries(&self) -> Result<(), String> {
        // SAFETY: the arena is quiescent per the caller (coordinator-
        // only call, no replay in flight), so no worker holds a live
        // view into the buffer while this shared borrow exists.
        let buf = unsafe { &(*self.lease.get()).buf };
        for &(a, b) in &self.canaries {
            for (i, v) in buf[a..b].iter().enumerate() {
                if v.to_bits() != CANARY_BITS {
                    return Err(format!(
                        "arena canary corrupted at element {} (hole {a}..{b}): \
                         a task wrote outside its slot view",
                        a + i
                    ));
                }
            }
        }
        Ok(())
    }
}

/// State shared between the coordinator and the worker pool.
struct PoolState {
    epoch: u64,
    remaining: usize,
    error: Option<String>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    go: Condvar,
    done: Condvar,
}

/// Shared state of the capped **work-sharing** pool: fewer workers than
/// streams, each worker picks up whichever stream is runnable. A stream
/// whose head task waits on an unfired event *parks* (releasing its
/// worker) instead of blocking inside [`EventTable::wait`]; recording
/// the event moves every parked stream back to `runnable`. All vectors
/// are preallocated to `n_streams` capacity, so steady-state scheduling
/// does not allocate.
struct CoopState {
    shutdown: bool,
    /// Streams ready to run. A stream appears at most once (it is either
    /// runnable, parked on exactly one event, held by a worker, or done).
    runnable: Vec<u32>,
    /// Per-event list of streams parked on it.
    parked: Vec<Vec<u32>>,
    /// Per-stream resume position (index into `tape.stream_ops`).
    cursors: Vec<u32>,
    /// Streams not yet finished this replay.
    active: usize,
    /// Workers currently executing a stream segment.
    busy: usize,
    error: Option<String>,
}

struct CoopShared {
    state: Mutex<CoopState>,
    /// Signalled when `runnable` gains entries (or on shutdown).
    work: Condvar,
    /// Signalled whenever the pool may have gone quiescent.
    done: Condvar,
}

/// One runnable stream of one leased context in the global queue.
type RunEntry = (Arc<ReplayJob>, u32);

/// Per-context coordination state for a [`SharedWorkerPool`] lease. One
/// job lives for the whole context lifetime and is re-armed per replay.
///
/// All quiescence bookkeeping is **job-local** (`running`/`queued`/
/// `active` below), never pool-global: a worker being "reclaimed" by the
/// pool to serve another context does not change this job's counters, so
/// the deadlock detector cannot mistake a temporarily worker-less
/// context for a stuck one (the scale-down race a pool-global
/// `busy == 0 && runnable.is_empty()` check would trip over).
struct ReplayJob {
    /// Pool-unique id (steal attribution + queue purging on cancel).
    id: u64,
    inner: Arc<ReplayInner>,
    state: Mutex<JobState>,
    /// Signalled whenever the job may have gone quiescent
    /// (`running == 0 && queued == 0`).
    done: Condvar,
    /// Segments of this job run by a worker whose previous segment
    /// belonged to a *different* job — the work actually stolen across
    /// contexts, surfaced as `LaneStat::steals` by the lane scheduler.
    steals: AtomicU64,
}

struct JobState {
    /// Per-stream resume position (index into `tape.stream_ops`).
    cursors: Vec<u32>,
    /// Per-event list of streams parked on it.
    parked: Vec<Vec<u32>>,
    /// Streams not yet finished this replay.
    active: usize,
    /// Workers currently executing a segment of THIS job.
    running: usize,
    /// Entries of this job sitting in (or being claimed from) the
    /// pool's global runnable queue.
    queued: usize,
    /// Set by [`cancel_job`]: drop pending work, suppress the deadlock
    /// detector, never run another segment.
    canceled: bool,
    error: Option<String>,
}

struct SharedPoolState {
    shutdown: bool,
    /// Global FIFO of runnable streams across every leased context —
    /// the single queue all workers steal from.
    runnable: std::collections::VecDeque<RunEntry>,
}

struct PoolCore {
    state: Mutex<SharedPoolState>,
    /// Signalled when `runnable` gains entries (or on shutdown).
    work: Condvar,
    next_job_id: AtomicU64,
    /// Total cross-context steals (see [`ReplayJob::steals`]).
    steals: AtomicU64,
    n_workers: usize,
}

/// Joins the pool's workers when the **last** [`SharedWorkerPool`]
/// handle drops. Workers hold only `Arc<PoolCore>`, so they never keep
/// the pool alive by themselves.
struct PoolWorkersGuard {
    core: Arc<PoolCore>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Drop for PoolWorkersGuard {
    fn drop(&mut self) {
        {
            let mut st = self.core.state.lock().unwrap();
            st.shutdown = true;
        }
        self.core.work.notify_all();
        for handle in self.workers.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
    }
}

/// A process-wide **work-stealing worker pool** shared by any number of
/// replay contexts ([`ExecOptions::shared_pool`]).
///
/// Where [`ExecOptions::max_workers`] caps threads *per context* (an
/// elastic serving deployment still pays cap × contexts threads), a
/// `SharedWorkerPool` owns exactly `n_workers` threads for the whole
/// process: contexts **lease** workers per replay by posting their
/// runnable streams to one global queue, and every worker steals
/// whichever context's stream is ready next. A stream that parks on an
/// unfired event releases its worker back to the *global* pool, so
/// lanes × streams can exceed the cores without oversubscription —
/// total live worker threads never exceed the pool size, however many
/// contexts lease from it.
///
/// Handles are cheap clones of one pool; workers shut down when the
/// last handle (including those held by leased contexts) drops.
#[derive(Clone)]
pub struct SharedWorkerPool {
    core: Arc<PoolCore>,
    _guard: Arc<PoolWorkersGuard>,
}

impl SharedWorkerPool {
    /// Spawn a pool of `n_workers` stealing workers (`n_workers` ≥ 1).
    pub fn new(n_workers: usize) -> SharedWorkerPool {
        assert!(n_workers >= 1, "shared worker pool needs at least one worker");
        let core = Arc::new(PoolCore {
            state: Mutex::new(SharedPoolState {
                shutdown: false,
                runnable: std::collections::VecDeque::new(),
            }),
            work: Condvar::new(),
            next_job_id: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            n_workers,
        });
        let workers = (0..n_workers)
            .map(|w| {
                let core = Arc::clone(&core);
                std::thread::Builder::new()
                    .name(format!("nimble-steal-w{w}"))
                    .spawn(move || stealing_worker_loop(core))
                    .expect("spawning shared pool worker")
            })
            .collect();
        SharedWorkerPool {
            _guard: Arc::new(PoolWorkersGuard {
                core: Arc::clone(&core),
                workers: Mutex::new(workers),
            }),
            core,
        }
    }

    /// The fixed worker-thread count — the hard cap on concurrently
    /// leased workers across ALL contexts.
    pub fn n_workers(&self) -> usize {
        self.core.n_workers
    }

    /// Total cross-context steals since the pool started: segments run
    /// by a worker whose previous segment belonged to a different
    /// context.
    pub fn total_steals(&self) -> u64 {
        self.core.steals.load(Ordering::Relaxed)
    }

    /// Streams currently waiting in the global runnable queue (tests,
    /// diagnostics).
    pub fn queued_streams(&self) -> usize {
        self.core.state.lock().unwrap().runnable.len()
    }
}

/// Signal `done` if the job has gone quiescent, first converting
/// genuine stuck-ness into an error. Stuck-ness is decided from
/// job-local counters ONLY (see [`ReplayJob`] docs): no segment of this
/// job is running and none is queued — so no future record can wake the
/// `active` parked streams. A canceled job is quiescent-by-request, not
/// deadlocked.
fn signal_if_quiescent(job: &ReplayJob, js: &mut JobState) {
    if js.running == 0 && js.queued == 0 {
        if js.active > 0 && !js.canceled && js.error.is_none() {
            js.error = Some(format!(
                "{} stream(s) parked with nothing runnable: unsafe sync plan or failed worker",
                js.active
            ));
        }
        job.done.notify_all();
    }
}

/// Cancel a leased context's job: purge its queued entries from the
/// global queue (a retired lane must not occupy pool slots), then wait
/// for any in-flight segments to finish so the arena is quiescent when
/// the context's memory is released. Safe to call with no replay in
/// flight (the common drop path) — it is then a no-op.
fn cancel_job(core: &PoolCore, job: &Arc<ReplayJob>) {
    {
        let mut js = job.state.lock().unwrap();
        js.canceled = true;
    }
    let mut purged = 0usize;
    {
        let mut st = core.state.lock().unwrap();
        st.runnable.retain(|(j, _)| {
            let keep = j.id != job.id;
            if !keep {
                purged += 1;
            }
            keep
        });
    }
    let mut js = job.state.lock().unwrap();
    js.queued -= purged;
    // Entries claimed (popped) but not yet checked in still count in
    // `queued`/`running`; the claimer observes `canceled` and signals.
    while js.running > 0 || js.queued > 0 {
        js = job.done.wait(js).unwrap();
    }
}

/// Run stream `stream` of a leased job from `*pos` until it finishes or
/// parks. Identical discipline to [`coop_run_segment`] except that
/// woken streams go to the POOL's global queue (any worker may resume
/// them) and parking/waking race-freedom hangs off the JOB lock: the
/// parker re-checks the event flag under `job.state`, and the recorder
/// drains `parked` under the same lock after its SeqCst flag store, so
/// a record between the lock-free check and the park is never missed.
fn shared_run_segment<'a>(
    inner: &'a ReplayInner,
    core: &PoolCore,
    job: &Arc<ReplayJob>,
    stream: usize,
    pos: &mut usize,
    scratch: &mut Vec<&'a [f32]>,
) -> Segment {
    let ops = inner.tape.stream_ops(stream);
    while *pos < ops.len() {
        let op_idx = ops[*pos] as usize;
        let op = inner.tape.op(op_idx);
        for &e in inner.tape.waits(op) {
            if !inner.events.is_set(e as usize) {
                let mut js = job.state.lock().unwrap();
                if !inner.events.is_set(e as usize) {
                    js.cursors[stream] = *pos as u32;
                    js.parked[e as usize].push(stream as u32);
                    return Segment::Parked;
                }
                // The event fired between the two checks; fall through.
            }
        }
        inner.run_op(op_idx, op, scratch, None);
        for &e in inner.tape.records(op) {
            inner.events.record(e as usize);
            let woken = {
                let mut js = job.state.lock().unwrap();
                let woken = std::mem::take(&mut js.parked[e as usize]);
                // Count them queued BEFORE they reach the global queue,
                // so a concurrent quiescence check cannot miss them.
                js.queued += woken.len();
                woken
            };
            if !woken.is_empty() {
                let mut st = core.state.lock().unwrap();
                for s in woken {
                    st.runnable.push_back((Arc::clone(job), s));
                }
                drop(st);
                core.work.notify_all();
            }
        }
        *pos += 1;
    }
    Segment::Finished
}

fn stealing_worker_loop(core: Arc<PoolCore>) {
    // One scratch allocation per worker, recycled across contexts: the
    // Vec is always CLEARED before its borrow lifetime is widened, so
    // only the raw allocation survives a context switch, never a
    // reference (see the transmute safety comment below).
    let mut store: Vec<&'static [f32]> = Vec::new();
    let mut last_job = u64::MAX;
    loop {
        let (job, stream) = {
            let mut st = core.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(entry) = st.runnable.pop_front() {
                    break entry;
                }
                st = core.work.wait(st).unwrap();
            }
        };
        let stream = stream as usize;
        // Claim the entry on its job; a canceled job's work is dropped.
        let mut pos = {
            let mut js = job.state.lock().unwrap();
            js.queued -= 1;
            if js.canceled {
                signal_if_quiescent(&job, &mut js);
                continue;
            }
            js.running += 1;
            js.cursors[stream] as usize
        };
        if job.id != last_job {
            if last_job != u64::MAX {
                core.steals.fetch_add(1, Ordering::Relaxed);
                job.steals.fetch_add(1, Ordering::Relaxed);
                if let Some(tel) = &job.inner.telemetry {
                    tel.event(EventKind::Steal, stream as u32, 0, 0);
                }
            }
            last_job = job.id;
        }
        let inner = Arc::clone(&job.inner);
        // `store` moves into the segment's shorter borrow lifetime
        // (covariance); presizing here keeps the per-task path growth-
        // free for whatever tape this context runs.
        let mut scratch: Vec<&[f32]> = store;
        if scratch.capacity() < inner.tape.max_args() {
            scratch.reserve(inner.tape.max_args());
        }
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            shared_run_segment(&inner, &core, &job, stream, &mut pos, &mut scratch)
        }));
        // Drop arena borrows before reporting in (see worker_loop).
        scratch.clear();
        // SAFETY: `scratch` is empty, so the Vec carries no references —
        // only its raw allocation — and widening the lifetime parameter
        // of a reference type it no longer contains is sound.
        store = unsafe { std::mem::transmute::<Vec<&[f32]>, Vec<&'static [f32]>>(scratch) };
        let mut js = job.state.lock().unwrap();
        match outcome {
            Ok(Segment::Finished) => js.active -= 1,
            // Cursor and park list already updated under the job lock.
            Ok(Segment::Parked) => {}
            Err(payload) => {
                let msg = panic_message(payload);
                js.error.get_or_insert(format!("stream {stream} worker panicked: {msg}"));
                // The stream will not run again this replay.
                js.active -= 1;
            }
        }
        js.running -= 1;
        signal_if_quiescent(&job, &mut js);
    }
}

/// Which worker-pool flavour drives a context.
enum PoolMode {
    /// One persistent worker per stream; waits block in the event table.
    PerStream(Arc<PoolShared>),
    /// `max_workers` shared workers over all streams; waits park.
    Shared(Arc<CoopShared>),
    /// A lease on a process-wide work-stealing pool; this context owns
    /// no threads at all.
    Leased { job: Arc<ReplayJob>, pool: SharedWorkerPool },
}

/// Everything the workers need, fixed for the context's lifetime.
struct ReplayInner {
    tape: ReplayTape,
    kernel: Box<dyn TapeKernel>,
    arena: SlotArena,
    /// The layout the arena's views were resolved from.
    plan: ArenaPlan,
    events: EventTable,
    weights: Vec<Vec<f32>>,
    /// Would-allocate events on the per-task path since the last reset.
    alloc_events: AtomicU64,
    /// Completion-stamp tracing (off by default: the shared stamp clock
    /// is an RMW on one cache line per task, instrumentation the
    /// serving hot path should not pay). Also gates the live-bytes
    /// accounting below.
    trace: AtomicBool,
    /// Seeded chaos injection: consulted per replay (entry faults) and
    /// per op (errors/stalls) when a [`FaultPlan`] with replay-level
    /// probabilities was configured ([`ExecOptions::fault`]).
    fault: Option<FaultInjector>,
    /// Flight recorder for replay-op spans and pool events
    /// ([`ExecOptions::telemetry`]). `None` costs one branch per task.
    telemetry: Option<Telemetry>,
    /// Stream id of each tape record (span attribution without a
    /// per-task lookup through the tape).
    stream_of: Vec<u32>,
    /// KiB of the pooled arena lease (0 = owned arena): sizes the
    /// ArenaAcquire/ArenaRelease telemetry events.
    arena_pooled_kib: u32,
    /// Per-record completion stamps (1-based; 0 = not completed).
    stamps: Vec<AtomicU64>,
    stamp_clock: AtomicU64,
    /// Reader count of each slot (static; reloads `reader_left` per replay).
    n_readers: Vec<u32>,
    /// Traced liveness accounting: a slot's rounded reservation counts
    /// as live from its defining record until its last reader finishes
    /// (forever, if nothing reads it — the DES uses the same rule, so
    /// predicted and measured peaks are comparable).
    reader_left: Vec<AtomicU32>,
    live_bytes: AtomicU64,
    peak_bytes: AtomicU64,
}

impl ReplayInner {
    /// Execute one stream's tape. Runs on that stream's worker (or, for
    /// the serial executor, inline over the merged order). The scratch
    /// elements borrow the arena through `&'a self`.
    fn run_stream<'a>(
        &'a self,
        stream: usize,
        scratch: &mut Vec<&'a [f32]>,
    ) -> Result<(), String> {
        // The borrow of `self` inside `scratch` is shared-only; arena
        // exclusivity is the sync plan's job (module docs).
        for &op_idx in self.tape.stream_ops(stream) {
            let op = self.tape.op(op_idx as usize);
            for &e in self.tape.waits(op) {
                self.events.wait(e as usize)?;
            }
            self.run_op(op_idx as usize, op, scratch, None);
            for &e in self.tape.records(op) {
                self.events.record(e as usize);
            }
        }
        Ok(())
    }

    /// Resolve args, execute, stamp. No events (callers handle those).
    /// When `sched_s` is given, the bookkeeping time (everything but the
    /// kernel) is accumulated into it — the serial-stats path; the
    /// parallel hot path passes `None` and pays no `Instant` calls.
    fn run_op<'a>(
        &'a self,
        op_idx: usize,
        op: &TapeOp,
        scratch: &mut Vec<&'a [f32]>,
        sched_s: Option<&mut f64>,
    ) {
        if op.role == TapeRole::Task {
            if let Some(inj) = &self.fault {
                match inj.op_fault(op_idx as u64) {
                    Some(OpFault::Delay) => std::thread::sleep(inj.delay()),
                    Some(OpFault::Error) => {
                        panic!("{}: op {op_idx} execution failed", crate::fault::INJECTED)
                    }
                    None => {}
                }
            }
            let t0 = sched_s.is_some().then(Instant::now);
            scratch.clear();
            if scratch.capacity() < self.tape.n_args(op) {
                self.alloc_events.fetch_add(1, Ordering::Relaxed);
            }
            for arg in self.tape.args(op) {
                scratch.push(match *arg {
                    // SAFETY: the slot's writer is ordered before us by
                    // the sync plan, so the view is immutable while we
                    // read it.
                    TapeArg::Slot(s) => unsafe { self.arena.get(s as usize) },
                    TapeArg::Weight(w) => self.weights[w as usize].as_slice(),
                });
            }
            // SAFETY: we hold the only live borrow of these bytes this
            // replay (sync plan + conflict-disjoint arena plan).
            let out = unsafe { self.arena.get_mut(op.out_slot as usize) };
            debug_assert_eq!(out.len(), op.out_len as usize, "slot views are sized at build");
            if let (Some(acc), Some(t0)) = (sched_s, t0) {
                *acc += t0.elapsed().as_secs_f64();
            }
            match self.telemetry.as_ref().filter(|t| t.enabled()) {
                Some(tel) => {
                    let k0 = Instant::now();
                    self.kernel.execute(op, scratch, out);
                    tel.replay_span(
                        self.stream_of[op_idx],
                        op.node as u32,
                        k0,
                        Instant::now(),
                    );
                }
                None => self.kernel.execute(op, scratch, out),
            }
        }
        if self.trace.load(Ordering::Relaxed) {
            let stamp = self.stamp_clock.fetch_add(1, Ordering::Relaxed) + 1;
            self.stamps[op_idx].store(stamp, Ordering::Relaxed);
            self.account_op(op);
        }
    }

    /// Traced liveness accounting: mark this record's slot live, retire
    /// argument slots whose last read this was. The instantaneous live
    /// set is always pairwise-conflicting under the happens-before plan,
    /// so `peak_bytes ≤ plan.arena_bytes` — asserted in tests and
    /// cross-checked against the DES's predicted peak
    /// ([`crate::sim::peak_reserved_bytes`]).
    fn account_op(&self, op: &TapeOp) {
        let bytes = self.plan.rounded_sizes[op.out_slot as usize];
        if bytes > 0 {
            let live = self.live_bytes.fetch_add(bytes, Ordering::Relaxed) + bytes;
            self.peak_bytes.fetch_max(live, Ordering::Relaxed);
        }
        for arg in self.tape.args(op) {
            if let TapeArg::Slot(s) = *arg {
                let s = s as usize;
                if self.reader_left[s].fetch_sub(1, Ordering::Relaxed) == 1 {
                    self.live_bytes.fetch_sub(self.plan.rounded_sizes[s], Ordering::Relaxed);
                }
            }
        }
    }

    fn reset_run_state(&self) {
        self.events.reset();
        self.stamp_clock.store(0, Ordering::Relaxed);
        for s in &self.stamps {
            s.store(0, Ordering::Relaxed);
        }
        self.live_bytes.store(0, Ordering::Relaxed);
        self.peak_bytes.store(0, Ordering::Relaxed);
        for (left, &n) in self.reader_left.iter().zip(&self.n_readers) {
            left.store(n, Ordering::Relaxed);
        }
    }

    fn fill_inputs(&self, inputs: &[&[f32]]) -> Result<(), String> {
        let expected = self.tape.input_slots();
        if inputs.len() != expected.len() {
            return Err(format!("expected {} input(s), got {}", expected.len(), inputs.len()));
        }
        for (&(slot, len), data) in expected.iter().zip(inputs) {
            if data.len() != len {
                return Err(format!("input for slot {slot}: length {} != {len}", data.len()));
            }
            // SAFETY: no replay is in flight (coordinator-only call),
            // so this is the only live view into the slot's bytes.
            let buf = unsafe { self.arena.get_mut(slot) };
            debug_assert_eq!(buf.len(), len, "input views are sized at build");
            buf.copy_from_slice(data);
        }
        Ok(())
    }
}

/// Human-readable text of a caught panic payload (also used by the
/// serving lanes' per-job panic guard).
pub(crate) fn panic_message(payload: Box<dyn Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn worker_loop(inner: Arc<ReplayInner>, shared: Arc<PoolShared>, stream: usize) {
    let mut scratch: Vec<&[f32]> = Vec::with_capacity(inner.tape.max_args());
    let mut last_epoch = 0u64;
    loop {
        {
            let mut st = shared.state.lock().unwrap();
            while st.epoch == last_epoch && !st.shutdown {
                st = shared.go.wait(st).unwrap();
            }
            if st.shutdown {
                return;
            }
            last_epoch = st.epoch;
        }
        let outcome = catch_unwind(AssertUnwindSafe(|| inner.run_stream(stream, &mut scratch)));
        // Drop all arena borrows before reporting done: the coordinator
        // may overwrite input slots as soon as the last worker checks in.
        scratch.clear();
        let mut st = shared.state.lock().unwrap();
        match outcome {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => {
                st.error.get_or_insert(format!("stream {stream}: {msg}"));
            }
            Err(payload) => {
                let msg = panic_message(payload);
                st.error.get_or_insert(format!("stream {stream} worker panicked: {msg}"));
            }
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done.notify_all();
        }
    }
}

/// What a work-sharing worker did with the stream it picked up.
enum Segment {
    /// Ran the stream to the end of its tape.
    Finished,
    /// Hit an unfired event; the stream is parked (cursor and park list
    /// were updated under the state lock inside the segment).
    Parked,
}

/// Run stream `stream` from `*pos` until it finishes or parks on an
/// unfired event. Parking happens under the state lock *after* a flag
/// re-check, so a record between the lock-free check and the park is
/// never missed; recording moves parked streams back to `runnable`
/// under the same lock, so a parked stream's cursor is always published
/// before another worker can resume it.
fn coop_run_segment<'a>(
    inner: &'a ReplayInner,
    shared: &CoopShared,
    stream: usize,
    pos: &mut usize,
    scratch: &mut Vec<&'a [f32]>,
) -> Segment {
    let ops = inner.tape.stream_ops(stream);
    while *pos < ops.len() {
        let op_idx = ops[*pos] as usize;
        let op = inner.tape.op(op_idx);
        for &e in inner.tape.waits(op) {
            if !inner.events.is_set(e as usize) {
                let mut st = shared.state.lock().unwrap();
                if !inner.events.is_set(e as usize) {
                    st.cursors[stream] = *pos as u32;
                    st.parked[e as usize].push(stream as u32);
                    return Segment::Parked;
                }
                // The event fired between the two checks; fall through.
            }
        }
        inner.run_op(op_idx, op, scratch, None);
        for &e in inner.tape.records(op) {
            inner.events.record(e as usize);
            let mut st = shared.state.lock().unwrap();
            let woke = !st.parked[e as usize].is_empty();
            while let Some(s) = st.parked[e as usize].pop() {
                st.runnable.push(s);
            }
            drop(st);
            if woke {
                shared.work.notify_all();
            }
        }
        *pos += 1;
    }
    Segment::Finished
}

fn coop_worker_loop(inner: Arc<ReplayInner>, shared: Arc<CoopShared>) {
    let mut scratch: Vec<&[f32]> = Vec::with_capacity(inner.tape.max_args());
    loop {
        let (stream, mut pos) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(s) = st.runnable.pop() {
                    st.busy += 1;
                    break (s as usize, st.cursors[s as usize] as usize);
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            coop_run_segment(&inner, &shared, stream, &mut pos, &mut scratch)
        }));
        // Drop arena borrows before reporting in (see worker_loop).
        scratch.clear();
        let mut st = shared.state.lock().unwrap();
        match outcome {
            Ok(Segment::Finished) => st.active -= 1,
            // Cursor and park list already updated under the lock.
            Ok(Segment::Parked) => {}
            Err(payload) => {
                let msg = panic_message(payload);
                st.error.get_or_insert(format!("stream {stream} worker panicked: {msg}"));
                // The stream will not run again this replay.
                st.active -= 1;
            }
        }
        st.busy -= 1;
        if st.busy == 0 && st.runnable.is_empty() {
            // Quiescent: either the replay completed, or every remaining
            // stream is parked on an event nobody will record. `busy == 0`
            // means no worker is mid-segment, so no record is pending and
            // the stuck-ness is definitive, not a transient.
            if st.active > 0 && st.error.is_none() {
                st.error = Some(format!(
                    "{} stream(s) parked with nothing runnable: unsafe sync plan or failed worker",
                    st.active
                ));
            }
            shared.done.notify_all();
        }
    }
}

/// Pool construction options ([`ReplayContext::with_options`]).
pub struct ExecOptions {
    /// Pre-staged weight table ([`TapeArg::Weight`] sources).
    pub weights: Vec<Vec<f32>>,
    /// Per-event / join deadline.
    pub timeout: Duration,
    /// Cap on pool threads. `None` (or a cap ≥ the tape's stream count)
    /// spawns the classic one-worker-per-stream pool with blocking event
    /// waits; a smaller cap switches to the work-sharing pool, where
    /// parked streams release their worker — the right shape when many
    /// lanes multiply total stream count past the physical cores.
    pub max_workers: Option<usize>,
    /// Lay every slot out in its own arena range (the per-slot-buffer
    /// baseline) instead of packing temporally-disjoint slots onto
    /// shared bytes per the happens-before plan. The differential
    /// harness replays both layouts and demands bit-identical outputs.
    pub unshared_slots: bool,
    /// Draw the arena's backing buffer from this pool (and return it on
    /// drop) instead of allocating a fresh one — serving lanes share one
    /// pool so rebuilt contexts recycle bucket-sized reservations.
    pub arena_pool: Option<ArenaPool>,
    /// Lease workers from this process-wide work-stealing pool instead
    /// of spawning any threads for this context ([`SharedWorkerPool`]).
    /// Takes precedence over `max_workers` — the pool size is the only
    /// thread cap. The elastic lane scheduler backs every lane's
    /// contexts with one such pool.
    pub shared_pool: Option<SharedWorkerPool>,
    /// Seeded chaos injection ([`crate::fault`]): per-op errors and
    /// stalls plus replay-entry faults (join timeout → poison, worker
    /// death, arena exhaustion). `None` (the default) injects nothing
    /// and costs nothing on the hot path.
    pub fault: Option<FaultPlan>,
    /// Flight recorder ([`crate::telemetry`]): when set and enabled,
    /// every task execution records a replay-op span (stream, op,
    /// start/end) into a preallocated per-thread ring, and pool/arena
    /// events (steals, lease acquire/release) are recorded too. `None`
    /// (the default) costs one branch per task.
    pub telemetry: Option<Telemetry>,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions {
            weights: Vec::new(),
            timeout: ReplayContext::DEFAULT_TIMEOUT,
            max_workers: None,
            unshared_slots: false,
            arena_pool: None,
            shared_pool: None,
            fault: None,
            telemetry: None,
        }
    }
}

/// A reusable replay context: slot arena + event table + persistent
/// worker pool for one compiled tape. Build once per (model, batch)
/// bucket; replay per request with zero per-task heap allocation.
pub struct ReplayContext {
    inner: Arc<ReplayInner>,
    mode: PoolMode,
    workers: Vec<std::thread::JoinHandle<()>>,
    timeout: Duration,
    /// Set when a join timed out with workers possibly still running:
    /// the arena can no longer be assumed exclusive, so replays refuse.
    poisoned: bool,
}

impl ReplayContext {
    /// Default per-event / join deadline: generous enough for CI, small
    /// enough that a genuine deadlock fails fast instead of hanging.
    pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(30);

    pub fn new(tape: ReplayTape, kernel: impl TapeKernel) -> ReplayContext {
        Self::with_config(tape, kernel, Vec::new(), Self::DEFAULT_TIMEOUT)
    }

    /// Full constructor: pre-staged weight table + watchdog timeout.
    ///
    /// # Panics
    ///
    /// Panics if the tape's happens-before structure does not cover its
    /// own slot dependencies (`ReplayTape::dependencies_are_synchronized`).
    /// The slot arena's soundness depends on that invariant, so a
    /// mis-built plan must fail loudly here rather than race at replay.
    pub fn with_config(
        tape: ReplayTape,
        kernel: impl TapeKernel,
        weights: Vec<Vec<f32>>,
        timeout: Duration,
    ) -> ReplayContext {
        Self::with_options(tape, kernel, ExecOptions { weights, timeout, ..Default::default() })
    }

    /// Constructor with explicit pool options (see [`ExecOptions`]).
    ///
    /// # Panics
    ///
    /// Panics on an unsynchronized tape, like [`with_config`](Self::with_config).
    pub fn with_options(
        tape: ReplayTape,
        kernel: impl TapeKernel,
        opts: ExecOptions,
    ) -> ReplayContext {
        assert!(
            tape.dependencies_are_synchronized(),
            "replay tape's sync plan does not cover its slot dependencies — \
             refusing to build a context that could race"
        );
        let timeout = opts.timeout;
        let slot_lens = tape.slot_lens();
        let n_ops = tape.n_ops();
        let n_events = tape.n_events();
        let n_streams = tape.n_streams();
        // Resolve the arena layout: stream-aware packing by default, the
        // end-to-end per-slot layout for the differential baseline.
        let slot_bytes = tape.slot_bytes();
        let plan = if opts.unshared_slots {
            ArenaPlan::unshared(&slot_bytes)
        } else {
            let conflicts = happens_before_conflicts(&tape);
            let plan = plan_with_conflicts(&slot_bytes, &conflicts);
            debug_assert!(
                plan_respects_conflicts(&conflicts, &plan),
                "arena plan violates its own conflict set"
            );
            plan
        };
        let arena_elems = (plan.arena_bytes / 4) as usize + GUARD_ELEMS;
        let arena_pooled_kib = match &opts.arena_pool {
            Some(_) => (arena_elems * 4 / 1024).max(1) as u32,
            None => 0,
        };
        let lease = match &opts.arena_pool {
            Some(pool) => {
                if let Some(tel) = &opts.telemetry {
                    tel.event(EventKind::ArenaAcquire, 0, arena_pooled_kib, 0);
                }
                pool.acquire(arena_elems)
            }
            None => ArenaLease::owned(),
        };
        let mut n_readers = vec![0u32; slot_lens.len()];
        for op in tape.ops() {
            for arg in tape.args(op) {
                if let TapeArg::Slot(s) = *arg {
                    n_readers[s as usize] += 1;
                }
            }
        }
        let mut stream_of = vec![0u32; n_ops];
        for s in 0..n_streams {
            for &op_idx in tape.stream_ops(s) {
                stream_of[op_idx as usize] = s as u32;
            }
        }
        let inner = Arc::new(ReplayInner {
            arena: SlotArena::new(&slot_lens, &plan, lease),
            plan,
            tape,
            kernel: Box::new(kernel),
            events: EventTable::new(n_events, timeout),
            weights: opts.weights,
            alloc_events: AtomicU64::new(0),
            fault: opts
                .fault
                .filter(|p| p.has_replay_faults())
                .map(FaultInjector::new),
            telemetry: opts.telemetry,
            stream_of,
            arena_pooled_kib,
            trace: AtomicBool::new(false),
            stamps: (0..n_ops).map(|_| AtomicU64::new(0)).collect(),
            stamp_clock: AtomicU64::new(0),
            reader_left: n_readers.iter().map(|&n| AtomicU32::new(n)).collect(),
            n_readers,
            live_bytes: AtomicU64::new(0),
            peak_bytes: AtomicU64::new(0),
        });
        if let Some(pool) = opts.shared_pool {
            let job = Arc::new(ReplayJob {
                id: pool.core.next_job_id.fetch_add(1, Ordering::Relaxed),
                inner: Arc::clone(&inner),
                state: Mutex::new(JobState {
                    cursors: vec![0u32; n_streams],
                    parked: (0..n_events).map(|_| Vec::with_capacity(n_streams)).collect(),
                    active: 0,
                    running: 0,
                    queued: 0,
                    canceled: false,
                    error: None,
                }),
                done: Condvar::new(),
                steals: AtomicU64::new(0),
            });
            return ReplayContext {
                inner,
                mode: PoolMode::Leased { job, pool },
                workers: Vec::new(),
                timeout,
                poisoned: false,
            };
        }
        let n_workers = opts.max_workers.unwrap_or(n_streams).clamp(1, n_streams.max(1));
        if n_workers >= n_streams {
            let shared = Arc::new(PoolShared {
                state: Mutex::new(PoolState {
                    epoch: 0,
                    remaining: 0,
                    error: None,
                    shutdown: false,
                }),
                go: Condvar::new(),
                done: Condvar::new(),
            });
            let workers = (0..n_streams)
                .map(|s| {
                    let inner = Arc::clone(&inner);
                    let shared = Arc::clone(&shared);
                    std::thread::Builder::new()
                        .name(format!("replay-s{s}"))
                        .spawn(move || worker_loop(inner, shared, s))
                        .expect("spawning replay worker")
                })
                .collect();
            ReplayContext {
                inner,
                mode: PoolMode::PerStream(shared),
                workers,
                timeout,
                poisoned: false,
            }
        } else {
            let shared = Arc::new(CoopShared {
                state: Mutex::new(CoopState {
                    shutdown: false,
                    runnable: Vec::with_capacity(n_streams),
                    parked: (0..n_events).map(|_| Vec::with_capacity(n_streams)).collect(),
                    cursors: vec![0u32; n_streams],
                    active: 0,
                    busy: 0,
                    error: None,
                }),
                work: Condvar::new(),
                done: Condvar::new(),
            });
            let workers = (0..n_workers)
                .map(|w| {
                    let inner = Arc::clone(&inner);
                    let shared = Arc::clone(&shared);
                    std::thread::Builder::new()
                        .name(format!("replay-w{w}"))
                        .spawn(move || coop_worker_loop(inner, shared))
                        .expect("spawning replay worker")
                })
                .collect();
            ReplayContext {
                inner,
                mode: PoolMode::Shared(shared),
                workers,
                timeout,
                poisoned: false,
            }
        }
    }

    /// Parallel replay: fill input slots, release the per-stream
    /// workers, and join. `&mut self` makes a context single-flight;
    /// independent contexts replay concurrently (the serving path keeps
    /// one per batch bucket).
    pub fn replay(&mut self, inputs: &[&[f32]]) -> Result<(), String> {
        if self.poisoned {
            return Err("context poisoned by an earlier timed-out replay".into());
        }
        self.inject_replay_fault()?;
        self.inner.fill_inputs(inputs)?;
        self.inner.reset_run_state();
        let result = match &self.mode {
            PoolMode::PerStream(shared) => {
                let shared = Arc::clone(shared);
                self.replay_per_stream(&shared)
            }
            PoolMode::Shared(shared) => {
                let shared = Arc::clone(shared);
                self.replay_shared_pool(&shared)
            }
            PoolMode::Leased { job, pool } => {
                let job = Arc::clone(job);
                let pool = pool.clone();
                self.replay_leased(&job, &pool)
            }
        };
        // Debug-mode overlap-corruption check: a task that wrote outside
        // its slot view trips an arena canary.
        if cfg!(debug_assertions) && result.is_ok() {
            self.inner.arena.check_canaries()?;
        }
        result
    }

    /// Release + join for the one-worker-per-stream pool.
    fn replay_per_stream(&mut self, shared: &PoolShared) -> Result<(), String> {
        {
            let mut st = shared.state.lock().unwrap();
            st.epoch += 1;
            st.remaining = self.workers.len();
            st.error = None;
        }
        shared.go.notify_all();

        let deadline = Instant::now() + self.timeout + self.timeout / 2;
        let mut st = shared.state.lock().unwrap();
        while st.remaining > 0 {
            let now = Instant::now();
            if now >= deadline {
                drop(st);
                self.poisoned = true;
                return Err("replay join timed out; context poisoned".into());
            }
            let (g, _timeout) = shared.done.wait_timeout(st, deadline - now).unwrap();
            st = g;
        }
        match st.error.take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Release + join for the capped work-sharing pool: mark every
    /// stream runnable at cursor 0, wake the workers, and wait until the
    /// pool is quiescent (no busy worker, nothing runnable) with either
    /// every stream finished or an error recorded.
    fn replay_shared_pool(&mut self, shared: &CoopShared) -> Result<(), String> {
        let n_streams = self.inner.tape.n_streams();
        {
            let mut st = shared.state.lock().unwrap();
            st.error = None;
            st.active = n_streams;
            st.busy = 0;
            st.runnable.clear();
            for p in &mut st.parked {
                p.clear();
            }
            for s in 0..n_streams {
                st.cursors[s] = 0;
                st.runnable.push(s as u32);
            }
        }
        shared.work.notify_all();

        let deadline = Instant::now() + self.timeout + self.timeout / 2;
        let mut st = shared.state.lock().unwrap();
        loop {
            let quiescent = st.busy == 0 && st.runnable.is_empty();
            if quiescent && (st.active == 0 || st.error.is_some()) {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                drop(st);
                self.poisoned = true;
                return Err("replay join timed out; context poisoned".into());
            }
            let (g, _timeout) = shared.done.wait_timeout(st, deadline - now).unwrap();
            st = g;
        }
        match st.error.take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Release + join for a lease on the process-wide work-stealing
    /// pool: arm the job (all streams runnable at cursor 0), post every
    /// stream to the pool's global queue, and wait until the job — not
    /// the pool — is quiescent with either every stream finished or an
    /// error recorded. Quiescence is judged from job-local counters
    /// only, so workers being stolen away to other contexts mid-replay
    /// can never read as a deadlock (see [`ReplayJob`]).
    fn replay_leased(
        &mut self,
        job: &Arc<ReplayJob>,
        pool: &SharedWorkerPool,
    ) -> Result<(), String> {
        let n_streams = self.inner.tape.n_streams();
        {
            let mut js = job.state.lock().unwrap();
            js.error = None;
            js.active = n_streams;
            js.running = 0;
            js.queued = n_streams;
            for p in &mut js.parked {
                p.clear();
            }
            for c in &mut js.cursors {
                *c = 0;
            }
        }
        {
            let mut st = pool.core.state.lock().unwrap();
            for s in 0..n_streams {
                st.runnable.push_back((Arc::clone(job), s as u32));
            }
        }
        pool.core.work.notify_all();

        let deadline = Instant::now() + self.timeout + self.timeout / 2;
        let mut js = job.state.lock().unwrap();
        loop {
            let quiescent = js.running == 0 && js.queued == 0;
            if quiescent && (js.active == 0 || js.error.is_some()) {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                drop(js);
                self.poisoned = true;
                return Err("replay join timed out; context poisoned".into());
            }
            let (g, _timeout) = job.done.wait_timeout(js, deadline - now).unwrap();
            js = g;
        }
        match js.error.take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Convenience for single-input tapes (the model-zoo case).
    pub fn replay_one(&mut self, input: &[f32]) -> Result<(), String> {
        self.replay(&[input])
    }

    /// Serial replay over the merged submission order on the calling
    /// thread. Events are skipped entirely — the submission order is
    /// topological, so FIFO order alone is safe. This is the differential
    /// oracle and the single-stream baseline.
    pub fn replay_serial(&mut self, inputs: &[&[f32]]) -> Result<(), String> {
        self.replay_serial_with_stats(inputs).map(|_| ())
    }

    /// Serial replay reporting the wall time spent on submission
    /// bookkeeping (argument resolution and slot lookup — everything but
    /// the kernel itself), the tape analogue of the eager engine's
    /// `sched_s`.
    pub fn replay_serial_with_stats(&mut self, inputs: &[&[f32]]) -> Result<f64, String> {
        if self.poisoned {
            return Err("context poisoned by an earlier timed-out replay".into());
        }
        self.inject_replay_fault()?;
        let inner = &self.inner;
        inner.fill_inputs(inputs)?;
        inner.reset_run_state();
        let mut scratch: Vec<&[f32]> = Vec::with_capacity(inner.tape.max_args());
        let mut sched_s = 0.0f64;
        for i in 0..inner.tape.n_ops() {
            // Same per-task body as the parallel workers (run_op), just
            // on one thread in merged order, with bookkeeping timed.
            let op = inner.tape.op(i);
            inner.run_op(i, op, &mut scratch, Some(&mut sched_s));
        }
        drop(scratch);
        if cfg!(debug_assertions) {
            self.inner.arena.check_canaries()?;
        }
        Ok(sched_s)
    }

    /// Serial replay replicating the *pre-tape* bookkeeping per task — a
    /// fresh argument vector and per-slot `Option` checks, exactly what
    /// `TaskSchedule::replay_with_stats` pays — as the measurement
    /// baseline for the bench. Returns bookkeeping seconds.
    pub fn replay_serial_alloc_baseline(&mut self, inputs: &[&[f32]]) -> Result<f64, String> {
        if self.poisoned {
            return Err("context poisoned by an earlier timed-out replay".into());
        }
        let inner = &self.inner;
        inner.fill_inputs(inputs)?;
        inner.reset_run_state();
        let mut written: Vec<bool> = vec![false; inner.tape.n_slots()];
        for &(slot, _) in inner.tape.input_slots() {
            written[slot] = true;
        }
        let mut sched_s = 0.0f64;
        for i in 0..inner.tape.n_ops() {
            let op = inner.tape.op(i);
            if op.role != TapeRole::Task {
                continue;
            }
            let t0 = Instant::now();
            // Fresh per-task argument vector: the allocation the tape
            // path removes.
            let mut args: Vec<&[f32]> = Vec::with_capacity(inner.tape.n_args(op));
            for arg in inner.tape.args(op) {
                args.push(match *arg {
                    TapeArg::Slot(s) => {
                        assert!(written[s as usize], "slot written before use");
                        // SAFETY: serial replay on this thread only;
                        // the writer completed earlier in topological
                        // order (asserted above).
                        unsafe { inner.arena.get(s as usize) }
                    }
                    TapeArg::Weight(w) => inner.weights[w as usize].as_slice(),
                });
            }
            // SAFETY: serial replay — this thread is the only
            // accessor, and `args` borrows disjoint slot views (the
            // plan verifier rejects self-dependencies).
            let out = unsafe { inner.arena.get_mut(op.out_slot as usize) };
            sched_s += t0.elapsed().as_secs_f64();
            inner.kernel.execute(op, &args, out);
            written[op.out_slot as usize] = true;
        }
        Ok(sched_s)
    }

    /// Consult the chaos injector at replay entry. An injected join
    /// timeout poisons the context exactly like a real timed-out join —
    /// the serving lanes' supervision path must replace the lane; the
    /// other replay faults are transient errors the retry policy covers.
    fn inject_replay_fault(&mut self) -> Result<(), String> {
        let Some(inj) = &self.inner.fault else { return Ok(()) };
        let (idx, fault) = inj.begin_replay();
        match fault {
            None => Ok(()),
            Some(ReplayFault::JoinTimeout) => {
                self.poisoned = true;
                Err(format!(
                    "{}: replay {idx} join timed out; context poisoned",
                    crate::fault::INJECTED
                ))
            }
            Some(ReplayFault::WorkerDeath) => {
                Err(format!("{}: worker died during replay {idx}", crate::fault::INJECTED))
            }
            Some(ReplayFault::ArenaExhausted) => Err(format!(
                "{}: arena capacity exhausted in replay {idx}",
                crate::fault::INJECTED
            )),
        }
    }

    /// A poisoned context may still have a straggler worker writing the
    /// arena (the join timed out), so reads would race — refuse loudly.
    fn assert_not_poisoned(&self) {
        assert!(
            !self.poisoned,
            "replay context poisoned by a timed-out join; workers may still be running"
        );
    }

    /// The replay result (output slot contents). Valid after a
    /// successful replay; a context is quiescent between replays.
    ///
    /// # Panics
    ///
    /// Panics on a poisoned context (timed-out join): workers may still
    /// be writing the arena, so reading would be a data race.
    pub fn output(&self) -> &[f32] {
        self.assert_not_poisoned();
        // SAFETY: no replay in flight (replay methods are blocking and
        // a timed-out join poisons the context, checked above).
        unsafe { self.inner.arena.get(self.inner.tape.output_slot()) }
    }

    /// Contents of an arbitrary slot (differential tests).
    ///
    /// # Panics
    ///
    /// Panics on a poisoned context, like [`output`](Self::output).
    pub fn slot(&self, slot: usize) -> &[f32] {
        self.assert_not_poisoned();
        // SAFETY: no replay in flight (see `output`).
        unsafe { self.inner.arena.get(slot) }
    }

    /// Enable or disable completion-stamp tracing for subsequent
    /// replays. Off by default — the shared stamp clock is per-task
    /// instrumentation the serving hot path should not pay.
    pub fn set_tracing(&self, on: bool) {
        self.inner.trace.store(on, Ordering::Relaxed);
    }

    /// Completion stamps per tape record (1-based global completion
    /// order; 0 = did not run or tracing was off). Only meaningful
    /// after a replay with [`set_tracing`](Self::set_tracing)`(true)`;
    /// cross-checked against the DES ordering in the executor tests.
    ///
    /// # Panics
    ///
    /// Panics on a poisoned context, like [`output`](Self::output).
    pub fn completion_stamps(&self) -> Vec<u64> {
        self.assert_not_poisoned();
        self.inner.stamps.iter().map(|s| s.load(Ordering::Relaxed)).collect()
    }

    /// The arena layout this context executes against.
    pub fn arena_plan(&self) -> &ArenaPlan {
        &self.inner.plan
    }

    /// Bytes of the single contiguous arena reservation (the packed
    /// footprint; excludes the debug tail guard).
    pub fn reserved_bytes(&self) -> u64 {
        self.inner.plan.arena_bytes
    }

    /// What per-slot allocation would reserve without lifetime sharing.
    pub fn unshared_bytes(&self) -> u64 {
        self.inner.plan.unshared_bytes()
    }

    /// Verify the arena's canary words (always available; the replay
    /// paths run this automatically in debug builds).
    ///
    /// # Panics
    ///
    /// Panics on a poisoned context, like [`output`](Self::output).
    pub fn check_canaries(&self) -> Result<(), String> {
        self.assert_not_poisoned();
        self.inner.arena.check_canaries()
    }

    /// Peak concurrently-live reserved bytes observed during the last
    /// traced replay ([`set_tracing`](Self::set_tracing)`(true)`; 0
    /// otherwise). A slot is live from its defining record until its
    /// last reader finishes — the same rule the DES prediction uses
    /// ([`crate::sim::peak_reserved_bytes`]), so the two are directly
    /// comparable; both are bounded by [`reserved_bytes`](Self::reserved_bytes).
    pub fn peak_live_bytes(&self) -> u64 {
        self.inner.peak_bytes.load(Ordering::Relaxed)
    }

    /// Would-allocate events observed on the per-task path since the
    /// last [`reset_alloc_events`](Self::reset_alloc_events).
    pub fn alloc_events(&self) -> u64 {
        self.inner.alloc_events.load(Ordering::Relaxed)
    }

    pub fn reset_alloc_events(&self) {
        self.inner.alloc_events.store(0, Ordering::Relaxed);
    }

    pub fn tape(&self) -> &ReplayTape {
        &self.inner.tape
    }

    pub fn n_streams(&self) -> usize {
        self.inner.tape.n_streams()
    }

    /// Pool threads actually spawned by THIS context (≤ streams in
    /// work-sharing mode; 0 on a [`SharedWorkerPool`] lease, which owns
    /// no threads at all).
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Cross-context steals this context received from its shared pool:
    /// segments run by a worker arriving from a different context
    /// (always 0 outside [`ExecOptions::shared_pool`] mode).
    pub fn steal_count(&self) -> u64 {
        match &self.mode {
            PoolMode::Leased { job, .. } => job.steals.load(Ordering::Relaxed),
            _ => 0,
        }
    }

    /// The flight recorder this context reports to, if any
    /// ([`ExecOptions::telemetry`]).
    pub fn telemetry(&self) -> Option<&Telemetry> {
        self.inner.telemetry.as_ref()
    }
}

impl Drop for ReplayInner {
    fn drop(&mut self) {
        // The pooled arena lease (inside `arena`) returns to its pool
        // when this struct's fields drop right after this runs — record
        // the release here so pool accounting has both edges.
        if self.arena_pooled_kib > 0 {
            if let Some(tel) = &self.telemetry {
                tel.event(EventKind::ArenaRelease, 0, self.arena_pooled_kib, 0);
            }
        }
    }
}

impl Drop for ReplayContext {
    fn drop(&mut self) {
        match &self.mode {
            PoolMode::PerStream(shared) => {
                {
                    let mut st = shared.state.lock().unwrap();
                    st.shutdown = true;
                }
                shared.go.notify_all();
            }
            PoolMode::Shared(shared) => {
                {
                    let mut st = shared.state.lock().unwrap();
                    st.shutdown = true;
                }
                shared.work.notify_all();
            }
            PoolMode::Leased { job, pool } => {
                // A retiring context must not leave queued entries
                // occupying the global pool, and its arena must be
                // quiescent before the memory is released.
                cancel_job(&pool.core, job);
            }
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aot::tape::ReplayTape;
    use crate::graph::Dag;
    use crate::matching::MatchingAlgo;
    use crate::models;
    use crate::stream::rewrite::rewrite;

    fn mini_tape() -> ReplayTape {
        let g = models::build("mini_inception", 1);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        ReplayTape::for_op_graph(&g, &plan, 512)
    }

    fn input_for(tape: &ReplayTape, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::Pcg32::new(seed);
        (0..tape.input_slots()[0].1).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect()
    }

    #[test]
    fn parallel_matches_serial_bitwise_on_mini_inception() {
        let tape = mini_tape();
        let input = input_for(&tape, 7);
        let mut par = ReplayContext::new(tape.clone(), SyntheticKernel);
        let mut ser = ReplayContext::new(tape.clone(), SyntheticKernel);
        par.replay_one(&input).unwrap();
        ser.replay_serial(&[&input]).unwrap();
        for s in 0..tape.n_slots() {
            let (a, b) = (par.slot(s), ser.slot(s));
            assert_eq!(a.len(), b.len(), "slot {s} length");
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "slot {s} diverged");
            }
        }
    }

    #[test]
    fn replay_is_repeatable_and_input_sensitive() {
        let tape = mini_tape();
        let (i1, i2) = (input_for(&tape, 1), input_for(&tape, 2));
        let mut ctx = ReplayContext::new(tape, SyntheticKernel);
        ctx.replay_one(&i1).unwrap();
        let out1: Vec<f32> = ctx.output().to_vec();
        ctx.replay_one(&i2).unwrap();
        let out2: Vec<f32> = ctx.output().to_vec();
        ctx.replay_one(&i1).unwrap();
        let out1b: Vec<f32> = ctx.output().to_vec();
        assert_eq!(out1, out1b, "same input must reproduce bitwise");
        assert_ne!(out1, out2, "different inputs must differ");
    }

    #[test]
    fn steady_state_is_allocation_free() {
        let tape = mini_tape();
        let input = input_for(&tape, 3);
        let mut ctx = ReplayContext::new(tape, SyntheticKernel);
        ctx.replay_one(&input).unwrap(); // warm-up
        ctx.reset_alloc_events();
        for _ in 0..5 {
            ctx.replay_one(&input).unwrap();
            ctx.replay_serial(&[&input]).unwrap();
        }
        assert_eq!(ctx.alloc_events(), 0, "hot path must not allocate");
    }

    #[test]
    fn wrong_input_length_is_rejected() {
        let tape = mini_tape();
        let mut ctx = ReplayContext::new(tape, SyntheticKernel);
        assert!(ctx.replay_one(&[0.0; 3]).is_err());
        assert!(ctx.replay(&[]).is_err());
    }

    #[test]
    fn injected_join_timeout_poisons_and_worker_death_is_transient() {
        let tape = mini_tape();
        let input = input_for(&tape, 11);
        let death = FaultPlan { worker_death: 1.0, ..FaultPlan::seeded(1) };
        let mut ctx = ReplayContext::with_options(
            tape.clone(),
            SyntheticKernel,
            ExecOptions { fault: Some(death), ..Default::default() },
        );
        let err = ctx.replay_one(&input).unwrap_err();
        assert!(err.contains("injected fault"), "{err}");
        assert!(!err.contains("poisoned"), "worker death is transient: {err}");
        let err2 = ctx.replay_one(&input).unwrap_err();
        assert!(!err2.contains("poisoned"), "still transient on the next replay: {err2}");

        let wedge = FaultPlan { join_timeout: 1.0, ..FaultPlan::seeded(2) };
        let mut ctx = ReplayContext::with_options(
            tape,
            SyntheticKernel,
            ExecOptions { fault: Some(wedge), ..Default::default() },
        );
        let err = ctx.replay_one(&input).unwrap_err();
        assert!(err.contains("injected fault"), "{err}");
        assert!(err.contains("poisoned"), "{err}");
        let err = ctx.replay_one(&input).unwrap_err();
        assert!(
            err.contains("poisoned by an earlier timed-out replay"),
            "context must stay poisoned: {err}"
        );
    }

    #[test]
    fn injected_op_error_fails_the_replay_without_poisoning() {
        let tape = mini_tape();
        let input = input_for(&tape, 12);
        // Every Task op panics; a short watchdog keeps streams that wait
        // on the dead streams' events from stalling the test.
        let plan = FaultPlan { op_error: 1.0, ..FaultPlan::seeded(3) };
        let mut ctx = ReplayContext::with_options(
            tape,
            SyntheticKernel,
            ExecOptions {
                fault: Some(plan),
                timeout: Duration::from_millis(200),
                ..Default::default()
            },
        );
        let err = ctx.replay_one(&input).unwrap_err();
        assert!(err.contains("injected fault"), "{err}");
        assert!(!err.contains("poisoned by an earlier"), "op errors are transient: {err}");
        let err2 = ctx.replay_one(&input).unwrap_err();
        assert!(!err2.contains("poisoned by an earlier"), "{err2}");
    }

    #[test]
    fn injected_fault_sequences_are_reproducible_across_contexts() {
        let tape = mini_tape();
        let input = input_for(&tape, 13);
        let plan = FaultPlan { worker_death: 0.4, ..FaultPlan::seeded(99) };
        let run = |plan: FaultPlan| -> Vec<bool> {
            let mut ctx = ReplayContext::with_options(
                tape.clone(),
                SyntheticKernel,
                ExecOptions { fault: Some(plan), ..Default::default() },
            );
            (0..12).map(|_| ctx.replay_one(&input).is_ok()).collect()
        };
        let a = run(plan.clone());
        let b = run(plan.clone());
        assert_eq!(a, b, "same plan, same fault sequence");
        let expect: Vec<bool> = (0..12).map(|i| plan.replay_fault(i).is_none()).collect();
        assert_eq!(a, expect, "executor mirrors the plan's stateless decisions");
    }

    #[test]
    fn event_table_record_then_wait() {
        let t = EventTable::new(2, Duration::from_millis(50));
        t.record(1);
        assert!(t.wait(1).is_ok());
        assert!(t.wait(0).is_err(), "unfired event must time out, not hang");
        t.reset();
        assert!(t.wait(1).is_err());
    }

    #[test]
    fn event_table_cross_thread_wakeup() {
        let t = Arc::new(EventTable::new(1, Duration::from_secs(5)));
        let t2 = Arc::clone(&t);
        let waiter = std::thread::spawn(move || t2.wait(0));
        std::thread::sleep(Duration::from_millis(10));
        t.record(0);
        assert!(waiter.join().unwrap().is_ok());
    }

    #[test]
    #[should_panic(expected = "does not cover")]
    fn context_refuses_unsynchronized_tapes() {
        let g = models::build("mini_inception", 1);
        let mut plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        for p in &mut plan.order {
            p.wait_events.clear(); // drop every cross-stream wait
        }
        let tape = ReplayTape::for_op_graph(&g, &plan, 64);
        let _ = ReplayContext::new(tape, SyntheticKernel);
    }

    #[test]
    fn capped_pool_matches_serial_bitwise() {
        // Work-sharing pool with fewer workers than streams must still
        // produce bit-identical slots (parked streams resume correctly).
        let tape = mini_tape();
        assert!(tape.n_streams() >= 2, "test premise: multi-stream tape");
        let input = input_for(&tape, 11);
        let mut ser = ReplayContext::new(tape.clone(), SyntheticKernel);
        ser.replay_serial(&[&input]).unwrap();
        for cap in [1usize, 2] {
            let mut par = ReplayContext::with_options(
                tape.clone(),
                SyntheticKernel,
                ExecOptions { max_workers: Some(cap), ..Default::default() },
            );
            assert_eq!(par.n_workers(), cap.min(tape.n_streams()));
            assert_eq!(par.n_streams(), tape.n_streams());
            for _ in 0..3 {
                par.replay_one(&input).unwrap();
                for s in 0..tape.n_slots() {
                    let (a, b) = (par.slot(s), ser.slot(s));
                    assert_eq!(a.len(), b.len(), "cap {cap}: slot {s} length");
                    for (x, y) in a.iter().zip(b) {
                        assert_eq!(x.to_bits(), y.to_bits(), "cap {cap}: slot {s} diverged");
                    }
                }
            }
        }
    }

    #[test]
    fn capped_pool_steady_state_is_allocation_free() {
        let tape = mini_tape();
        let input = input_for(&tape, 4);
        let mut ctx = ReplayContext::with_options(
            tape,
            SyntheticKernel,
            ExecOptions { max_workers: Some(1), ..Default::default() },
        );
        ctx.replay_one(&input).unwrap(); // warm-up
        ctx.reset_alloc_events();
        for _ in 0..5 {
            ctx.replay_one(&input).unwrap();
        }
        assert_eq!(ctx.alloc_events(), 0, "work-sharing hot path must not allocate");
    }

    #[test]
    fn capped_pool_on_random_layered_dags_matches_serial() {
        let mut rng = crate::util::Pcg32::new(0xBEEF);
        for _ in 0..5 {
            let g = crate::graph::gen::layered_dag(&mut rng, 3, 4, 2);
            let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
            let tape = ReplayTape::for_dag(&g, &plan);
            let mut ser = ReplayContext::new(tape.clone(), SyntheticKernel);
            ser.replay_serial(&[]).unwrap();
            let cap = 1 + (g.n_nodes() % 2); // alternate 1 and 2 workers
            let mut par = ReplayContext::with_options(
                tape.clone(),
                SyntheticKernel,
                ExecOptions { max_workers: Some(cap), ..Default::default() },
            );
            par.replay(&[]).unwrap();
            assert_eq!(par.output(), ser.output());
        }
    }

    #[test]
    fn diamond_dag_tape_executes_in_parallel() {
        let mut g: Dag<()> = Dag::new();
        for _ in 0..4 {
            g.add_node(());
        }
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
        assert_eq!(plan.n_streams, 2);
        let tape = ReplayTape::for_dag(&g, &plan);
        let mut par = ReplayContext::new(tape.clone(), SyntheticKernel);
        let mut ser = ReplayContext::new(tape, SyntheticKernel);
        par.set_tracing(true);
        par.replay(&[]).unwrap();
        ser.replay_serial(&[]).unwrap();
        assert_eq!(par.output(), ser.output());
        // every record completed exactly once
        let stamps = par.completion_stamps();
        assert!(stamps.iter().all(|&s| s > 0));
        let mut sorted = stamps.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), stamps.len(), "stamps must be unique");
    }

    #[test]
    fn arena_packs_below_unshared_with_intact_canaries() {
        let tape = mini_tape();
        let input = input_for(&tape, 6);
        let mut ctx = ReplayContext::new(tape, SyntheticKernel);
        assert!(
            ctx.reserved_bytes() < ctx.unshared_bytes(),
            "packed arena {} must beat unshared {}",
            ctx.reserved_bytes(),
            ctx.unshared_bytes()
        );
        for _ in 0..3 {
            ctx.replay_one(&input).unwrap();
            ctx.replay_serial(&[&input]).unwrap();
        }
        ctx.check_canaries().expect("no task may write outside its slot view");
    }

    #[test]
    fn unshared_layout_is_bit_identical_to_packed_arena() {
        let tape = mini_tape();
        let input = input_for(&tape, 8);
        let mut packed = ReplayContext::new(tape.clone(), SyntheticKernel);
        let mut unshared = ReplayContext::with_options(
            tape,
            SyntheticKernel,
            ExecOptions { unshared_slots: true, ..Default::default() },
        );
        assert_eq!(unshared.reserved_bytes(), unshared.unshared_bytes());
        assert!(packed.reserved_bytes() < unshared.reserved_bytes());
        packed.replay_one(&input).unwrap();
        unshared.replay_one(&input).unwrap();
        assert_eq!(packed.output(), unshared.output(), "layout must not leak into results");
    }

    #[test]
    fn pooled_arena_is_recycled_across_context_builds() {
        let pool = crate::aot::memory::ArenaPool::new();
        let tape = mini_tape();
        let input = input_for(&tape, 9);
        let expect: Vec<f32> = {
            let mut ctx = ReplayContext::with_options(
                tape.clone(),
                SyntheticKernel,
                ExecOptions { arena_pool: Some(pool.clone()), ..Default::default() },
            );
            ctx.replay_one(&input).unwrap();
            ctx.output().to_vec()
        };
        let stats = pool.stats();
        assert_eq!((stats.acquires, stats.hits), (1, 0));
        assert!(stats.resident_bytes > 0, "dropping the context returns the arena");
        assert_eq!(stats.leased_bytes, 0);

        // A rebuild of the same shape draws the recycled buffer — and
        // the recycled (dirty) arena replays bit-identically.
        let mut ctx = ReplayContext::with_options(
            tape,
            SyntheticKernel,
            ExecOptions { arena_pool: Some(pool.clone()), ..Default::default() },
        );
        let stats = pool.stats();
        assert_eq!((stats.acquires, stats.hits), (2, 1));
        ctx.replay_one(&input).unwrap();
        assert_eq!(ctx.output(), expect.as_slice());
    }

    #[test]
    fn traced_replay_peak_live_bytes_is_bounded_by_the_reservation() {
        let tape = mini_tape();
        let input = input_for(&tape, 10);
        let mut ctx = ReplayContext::new(tape, SyntheticKernel);
        assert_eq!(ctx.peak_live_bytes(), 0, "untraced replays pay no accounting");
        ctx.set_tracing(true);
        ctx.replay_one(&input).unwrap();
        let peak = ctx.peak_live_bytes();
        let max_slot = ctx.arena_plan().rounded_sizes.iter().copied().max().unwrap();
        assert!(peak >= max_slot, "peak {peak} below the largest slot {max_slot}");
        assert!(
            peak <= ctx.reserved_bytes(),
            "measured peak {peak} exceeds the reservation {}",
            ctx.reserved_bytes()
        );
        // Serial replay of the same tape accounts deterministically and
        // stays within the same bound.
        ctx.replay_serial(&[&input]).unwrap();
        let serial_peak = ctx.peak_live_bytes();
        assert!(serial_peak >= max_slot && serial_peak <= ctx.reserved_bytes());
    }

    fn leased(tape: ReplayTape, pool: &SharedWorkerPool) -> ReplayContext {
        ReplayContext::with_options(
            tape,
            SyntheticKernel,
            ExecOptions { shared_pool: Some(pool.clone()), ..Default::default() },
        )
    }

    #[test]
    fn one_stealing_worker_serves_two_contexts_bit_identically() {
        // A single shared worker must drive two multi-stream contexts to
        // completion (parked streams resume via the global queue), the
        // results must match the serial oracle bitwise, and alternating
        // replays must show up in the steal counters.
        let tape = mini_tape();
        assert!(tape.n_streams() >= 2, "test premise: multi-stream tape");
        let input = input_for(&tape, 21);
        let mut ser = ReplayContext::new(tape.clone(), SyntheticKernel);
        ser.replay_serial(&[&input]).unwrap();

        let pool = SharedWorkerPool::new(1);
        assert_eq!(pool.n_workers(), 1);
        let mut a = leased(tape.clone(), &pool);
        let mut b = leased(tape.clone(), &pool);
        assert_eq!(a.n_workers(), 0, "a lease owns no threads");
        for _ in 0..3 {
            a.replay_one(&input).unwrap();
            b.replay_one(&input).unwrap();
        }
        for ctx in [&a, &b] {
            for s in 0..tape.n_slots() {
                let (x, y) = (ctx.slot(s), ser.slot(s));
                assert_eq!(x.len(), y.len(), "slot {s} length");
                for (p, q) in x.iter().zip(y) {
                    assert_eq!(p.to_bits(), q.to_bits(), "slot {s} diverged");
                }
            }
        }
        // The lone worker alternated jobs ≥ once per b-replay.
        assert!(pool.total_steals() >= 3, "steals: {}", pool.total_steals());
        assert_eq!(a.steal_count() + b.steal_count(), pool.total_steals());
        assert_eq!(pool.queued_streams(), 0, "quiescent pool holds no queued streams");
    }

    #[test]
    fn leased_contexts_replay_concurrently_from_many_threads() {
        let tape = mini_tape();
        let input = input_for(&tape, 22);
        let mut ser = ReplayContext::new(tape.clone(), SyntheticKernel);
        ser.replay_serial(&[&input]).unwrap();
        let expect: Vec<f32> = ser.output().to_vec();

        let pool = SharedWorkerPool::new(2);
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let mut ctx = leased(tape.clone(), &pool);
                let input = input.clone();
                std::thread::spawn(move || {
                    for _ in 0..4 {
                        ctx.replay_one(&input).unwrap();
                    }
                    ctx.output().to_vec()
                })
            })
            .collect();
        for h in handles {
            let got = h.join().expect("leased replay thread");
            assert_eq!(got, expect, "concurrent leases must not corrupt each other");
        }
    }

    #[test]
    fn leased_steady_state_is_allocation_free() {
        let tape = mini_tape();
        let input = input_for(&tape, 23);
        let pool = SharedWorkerPool::new(2);
        let mut ctx = leased(tape, &pool);
        ctx.replay_one(&input).unwrap(); // warm-up
        ctx.reset_alloc_events();
        for _ in 0..5 {
            ctx.replay_one(&input).unwrap();
        }
        assert_eq!(ctx.alloc_events(), 0, "stealing hot path must not allocate");
    }

    #[test]
    fn retiring_a_leased_context_does_not_deadlock_survivors() {
        // The scale-down regression: dropping one lease (a retired
        // lane's context) while a sibling is mid-replay-queue must purge
        // only the retiree's work — the survivor completes without a
        // spurious "parked with nothing runnable" error, and the retire
        // itself does not hang.
        let tape = mini_tape();
        let input = input_for(&tape, 24);
        let pool = SharedWorkerPool::new(1);
        let survivor_tape = tape.clone();
        let survivor_pool = pool.clone();
        let survivor_input = input.clone();
        let survivor = std::thread::spawn(move || {
            let mut ctx = leased(survivor_tape, &survivor_pool);
            let mut outs = Vec::new();
            for _ in 0..8 {
                ctx.replay_one(&survivor_input).unwrap();
                outs.push(ctx.output().to_vec());
            }
            outs
        });
        // Churn: build, replay once, and retire leases while the
        // survivor replays on the same lone worker.
        for _ in 0..4 {
            let mut ctx = leased(tape.clone(), &pool);
            ctx.replay_one(&input).unwrap();
            drop(ctx);
            let never_replayed = leased(tape.clone(), &pool);
            drop(never_replayed); // cancel with no replay in flight
        }
        let outs = survivor.join().expect("survivor thread");
        let mut ser = ReplayContext::new(tape, SyntheticKernel);
        ser.replay_serial(&[&input]).unwrap();
        for out in outs {
            assert_eq!(out, ser.output(), "survivor output diverged under churn");
        }
        assert_eq!(pool.queued_streams(), 0);
    }

    #[test]
    fn stealing_pool_on_random_layered_dags_matches_serial() {
        let pool = SharedWorkerPool::new(2);
        let mut rng = crate::util::Pcg32::new(0xFEED);
        for _ in 0..5 {
            let g = crate::graph::gen::layered_dag(&mut rng, 3, 4, 2);
            let plan = rewrite(&g, MatchingAlgo::HopcroftKarp);
            let tape = ReplayTape::for_dag(&g, &plan);
            let mut ser = ReplayContext::new(tape.clone(), SyntheticKernel);
            ser.replay_serial(&[]).unwrap();
            let mut par = leased(tape, &pool);
            par.replay(&[]).unwrap();
            assert_eq!(par.output(), ser.output());
        }
    }
}
