//! # Nimble — reproduction of *Nimble: Lightweight and Parallel GPU Task
//! Scheduling for Deep Learning* (Kwon, Yu, Jeong, Chun — NeurIPS 2020)
//!
//! A three-layer Rust + JAX + Pallas serving stack:
//!
//! * **L3 (this crate)** — the paper's system: the stream-assignment
//!   algorithm (Algorithm 1: MEG → bipartite maximum matching → chain
//!   partition), the graph rewriter, the ahead-of-time (AoT) task scheduler
//!   with pre-run interception and **stream-aware memory reservation**
//!   ([`aot::memory`]: happens-before lifetimes → conflict-packed shared
//!   arena → pooled reservations), the **parallel multi-stream replay
//!   executor** (per-stream submission tapes driven by a persistent worker
//!   pool through one contiguous slot arena and an event table — zero heap
//!   allocation per task on the steady-state path), a
//!   discrete-event virtual-GPU simulator that replays the *same* tapes to
//!   predict multi-stream speedups, framework baseline profiles, an
//!   operator-graph model zoo covering every network in the paper's
//!   evaluation, and a batched serving front-end behind ONE runtime
//!   façade ([`serving::Runtime`]): a fluent builder composes engines,
//!   batch buckets, pools and elastic scaling, and exactly two submit
//!   paths — blocking `infer(InferRequest)` and waitable
//!   `submit(InferRequest) -> Ticket` — carry bucket hints and
//!   per-request **deadlines** (expired-while-queued requests are shed
//!   before execution). Batch buckets replay on independent contexts,
//!   pipelined end-to-end by the lane scheduler ([`serving::lanes`]): a
//!   bounded MPMC admission queue feeding one lane (thread + engine)
//!   per batch bucket, validated bit-exact against serial replay by a
//!   randomized differential harness (`tests/prop_harness.rs`).
//! * **L2 (python/compile/model.py)** — JAX computation graphs (built-time
//!   only), lowered per-operator to HLO text artifacts.
//! * **L1 (python/compile/kernels/)** — Pallas kernels (MXU-tiled matmul,
//!   im2col conv, fused epilogues) checked against pure-jnp oracles.
//!
//! ## Execution paths
//!
//! The **tape path** (always available): [`stream`] computes the launch
//! plan, [`aot::tape`] flattens it into per-stream tapes of integer-
//! resolved records, and [`engine::executor`] replays them — in parallel
//! with event-based cross-stream synchronization (the
//! `cudaStreamWaitEvent` pattern), or serially as the differential
//! oracle. [`sim::simulate_tape`] runs the identical artifact on the
//! virtual GPU, so predicted speedups and measured interleavings are
//! cross-checked in `tests/integration_executor.rs`.
//!
//! The **PJRT path** (feature `xla`): [`runtime`] loads the AOT artifacts
//! through the PJRT C API and [`aot::schedule`] replays pre-resolved
//! executables; Python never runs on the request path. Without the
//! feature the crate builds against a stub `xla` crate and every PJRT
//! entry point reports itself unavailable.

// The unsafe surface (the executor's slot arena and the PJRT argument
// marshalling) is small and every site must carry its proof: a
// `// SAFETY:` comment tying it to the sync-plan / arena-plan contract
// the static verifier (`aot::verify`) certifies at build time.
#![deny(unsafe_op_in_unsafe_fn)]
#![deny(clippy::undocumented_unsafe_blocks)]

pub mod aot;
pub mod baselines;
pub mod cluster;
pub mod coordinator;
pub mod figures;
pub mod serving;
#[cfg(feature = "xla")]
pub mod training;
pub mod engine;
pub mod fault;
pub mod runtime;
pub mod graph;
pub mod matching;
pub mod models;
pub mod ops;
pub mod sim;
pub mod stream;
pub mod telemetry;
pub mod util;
