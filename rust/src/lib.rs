//! # Nimble — reproduction of *Nimble: Lightweight and Parallel GPU Task
//! Scheduling for Deep Learning* (Kwon, Yu, Jeong, Chun — NeurIPS 2020)
//!
//! A three-layer Rust + JAX + Pallas serving stack:
//!
//! * **L3 (this crate)** — the paper's system: the stream-assignment
//!   algorithm (Algorithm 1: MEG → bipartite maximum matching → chain
//!   partition), the graph rewriter, the ahead-of-time (AoT) task scheduler
//!   with pre-run interception and memory reservation, the multi-stream
//!   replay engine, a discrete-event virtual-GPU simulator with framework
//!   baseline profiles, an operator-graph model zoo covering every network
//!   in the paper's evaluation, and a batched serving front-end.
//! * **L2 (python/compile/model.py)** — JAX computation graphs (built-time
//!   only), lowered per-operator to HLO text artifacts.
//! * **L1 (python/compile/kernels/)** — Pallas kernels (MXU-tiled matmul,
//!   im2col conv, fused epilogues) checked against pure-jnp oracles.
//!
//! Python never runs on the request path: the `runtime` module loads the AOT
//! artifacts through the PJRT C API (`xla` crate) and the replay engine
//! submits pre-scheduled tasks directly.

pub mod aot;
pub mod baselines;
pub mod coordinator;
pub mod figures;
pub mod serving;
pub mod training;
pub mod engine;
pub mod runtime;
pub mod graph;
pub mod matching;
pub mod models;
pub mod ops;
pub mod sim;
pub mod stream;
pub mod util;
