//! Minimum equivalent graph (Step 1 of Algorithm 1).
//!
//! For a finite DAG the MEG coincides with the transitive reduction and is
//! unique (Aho, Garey & Ullman 1972; the paper cites Hsu 1975): it keeps
//! exactly the edges `(u, v)` for which no other path `u ⇝ v` exists
//! (Lemma 1 in the paper's appendix). With the transitive closure in hand,
//! an edge `(u, v)` is redundant iff some other successor `w` of `u`
//! reaches `v`.

use super::dag::{Dag, NodeId};
use super::reach::Reachability;

/// Compute the MEG edge set. Returns a structure-only DAG over the same node
/// ids containing exactly the non-redundant edges.
pub fn minimum_equivalent_graph<N>(g: &Dag<N>) -> Dag<()> {
    let reach = Reachability::compute(g);
    minimum_equivalent_graph_with(g, &reach)
}

/// Same as [`minimum_equivalent_graph`] but reusing a precomputed closure.
pub fn minimum_equivalent_graph_with<N>(g: &Dag<N>, reach: &Reachability) -> Dag<()> {
    g.filter_edges(|u, v| !is_redundant(g, reach, u, v))
}

/// Edge (u, v) is redundant iff a path u ⇝ v of length ≥ 2 exists, i.e. some
/// other direct successor w of u reaches v (or equals an intermediate hop).
fn is_redundant<N>(g: &Dag<N>, reach: &Reachability, u: NodeId, v: NodeId) -> bool {
    g.successors(u).iter().any(|&w| w != v && reach.reaches(w, v))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::random_dag;
    use crate::graph::Reachability;
    use crate::util::Pcg32;

    #[test]
    fn removes_shortcut_edge() {
        // 0 -> 1 -> 2 plus shortcut 0 -> 2; MEG drops the shortcut.
        let mut g: Dag<()> = Dag::new();
        for _ in 0..3 {
            g.add_node(());
        }
        g.add_edge(0, 1);
        g.add_edge(1, 2);
        g.add_edge(0, 2);
        let meg = minimum_equivalent_graph(&g);
        assert_eq!(meg.n_edges(), 2);
        assert!(meg.has_edge(0, 1) && meg.has_edge(1, 2) && !meg.has_edge(0, 2));
    }

    #[test]
    fn diamond_is_already_minimal() {
        let mut g: Dag<()> = Dag::new();
        for _ in 0..4 {
            g.add_node(());
        }
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        let meg = minimum_equivalent_graph(&g);
        assert_eq!(meg.n_edges(), 4);
    }

    #[test]
    fn preserves_reachability_on_random_graphs() {
        let mut rng = Pcg32::new(0x1234);
        for _ in 0..25 {
            let g = random_dag(&mut rng, 30, 0.15);
            let meg = minimum_equivalent_graph(&g);
            let r1 = Reachability::compute(&g);
            let r2 = Reachability::compute(&meg);
            for u in 0..g.n_nodes() {
                for v in 0..g.n_nodes() {
                    assert_eq!(r1.reaches(u, v), r2.reaches(u, v), "({u},{v})");
                }
            }
        }
    }

    #[test]
    fn is_minimal_on_random_graphs() {
        // Removing ANY edge of the MEG must change reachability (Lemma 1).
        let mut rng = Pcg32::new(0x5678);
        for _ in 0..10 {
            let g = random_dag(&mut rng, 20, 0.2);
            let meg = minimum_equivalent_graph(&g);
            for (u, v) in meg.edges() {
                let pruned = meg.filter_edges(|a, b| !(a == u && b == v));
                let r = Reachability::compute(&pruned);
                assert!(!r.reaches(u, v), "edge ({u},{v}) was removable — MEG not minimal");
            }
        }
    }

    #[test]
    fn meg_of_meg_is_identity() {
        let mut rng = Pcg32::new(0x9AB);
        for _ in 0..10 {
            let g = random_dag(&mut rng, 25, 0.2);
            let meg = minimum_equivalent_graph(&g);
            let meg2 = minimum_equivalent_graph(&meg);
            let mut e1 = meg.edges();
            let mut e2 = meg2.edges();
            e1.sort_unstable();
            e2.sort_unstable();
            assert_eq!(e1, e2);
        }
    }
}
