//! Computation-graph core: a generic DAG with the structural algorithms the
//! paper's stream-assignment pipeline needs — topological ordering,
//! reachability (transitive closure), and the minimum equivalent graph
//! (transitive reduction, Hsu 1975), plus DOT export and seeded random-DAG
//! generators for property tests.

pub mod dag;
pub mod dot;
pub mod gen;
pub mod meg;
pub mod reach;
pub mod topo;

pub use dag::{Dag, NodeId};
pub use meg::{minimum_equivalent_graph, minimum_equivalent_graph_with};
pub use reach::Reachability;
pub use topo::{topo_order, topo_positions};
