//! Graphviz DOT export for debugging stream assignments and rewritten graphs.

use super::dag::{Dag, NodeId};

/// Render a DAG to DOT. `label` supplies each node's label; `cluster`
/// optionally groups nodes (e.g. by assigned stream) with a color.
pub fn to_dot<N>(
    g: &Dag<N>,
    name: &str,
    mut label: impl FnMut(NodeId, &N) -> String,
    mut group: impl FnMut(NodeId) -> Option<usize>,
) -> String {
    const PALETTE: [&str; 10] = [
        "#a6cee3", "#1f78b4", "#b2df8a", "#33a02c", "#fb9a99", "#e31a1c", "#fdbf6f",
        "#ff7f00", "#cab2d6", "#6a3d9a",
    ];
    let mut s = format!("digraph {name} {{\n  rankdir=TB;\n  node [shape=box, style=filled];\n");
    for (id, n) in g.nodes() {
        let fill = match group(id) {
            Some(gid) => PALETTE[gid % PALETTE.len()],
            None => "#ffffff",
        };
        s.push_str(&format!(
            "  n{id} [label=\"{}\", fillcolor=\"{fill}\"];\n",
            label(id, n).replace('"', "'")
        ));
    }
    for (u, v) in g.edges() {
        s.push_str(&format!("  n{u} -> n{v};\n"));
    }
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nodes_edges_and_groups() {
        let mut g = Dag::new();
        let a = g.add_node("conv");
        let b = g.add_node("relu");
        g.add_edge(a, b);
        let dot = to_dot(&g, "t", |_, n| n.to_string(), |id| Some(id));
        assert!(dot.contains("digraph t"));
        assert!(dot.contains("n0 [label=\"conv\""));
        assert!(dot.contains("n0 -> n1;"));
        assert!(dot.contains("#a6cee3")); // group 0 color
    }

    #[test]
    fn quotes_escaped() {
        let mut g = Dag::new();
        g.add_node("a\"b");
        let dot = to_dot(&g, "q", |_, n| n.to_string(), |_| None);
        assert!(dot.contains("a'b"));
    }
}
