//! Directed acyclic graph with payload-carrying nodes.
//!
//! `Dag<N>` is the substrate for every graph in the system: operator graphs
//! (`N = ops::Op`), rewritten graphs with event nodes, and the synthetic DAGs
//! used by the property tests. Node identity is a dense `usize` index so the
//! structural algorithms can use flat vectors and bitsets.

/// Dense node identifier.
pub type NodeId = usize;

/// A DAG with adjacency in both directions.
///
/// Acyclicity is *not* enforced on every `add_edge` (that would be O(V+E)
/// each); callers build graphs and the algorithms that require acyclicity
/// (`topo_order`) detect cycles and report them. `validate()` runs the full
/// check on demand.
#[derive(Debug, Clone)]
pub struct Dag<N> {
    nodes: Vec<N>,
    succ: Vec<Vec<NodeId>>,
    pred: Vec<Vec<NodeId>>,
    n_edges: usize,
}

impl<N> Default for Dag<N> {
    fn default() -> Self {
        Self::new()
    }
}

impl<N> Dag<N> {
    pub fn new() -> Self {
        Dag { nodes: Vec::new(), succ: Vec::new(), pred: Vec::new(), n_edges: 0 }
    }

    pub fn with_capacity(n: usize) -> Self {
        Dag {
            nodes: Vec::with_capacity(n),
            succ: Vec::with_capacity(n),
            pred: Vec::with_capacity(n),
            n_edges: 0,
        }
    }

    /// Add a node, returning its id.
    pub fn add_node(&mut self, payload: N) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(payload);
        self.succ.push(Vec::new());
        self.pred.push(Vec::new());
        id
    }

    /// Add a directed edge `u -> v`. Duplicate edges are ignored.
    pub fn add_edge(&mut self, u: NodeId, v: NodeId) {
        assert!(u < self.nodes.len() && v < self.nodes.len(), "edge endpoint out of range");
        assert_ne!(u, v, "self-loop would make the graph cyclic");
        if self.succ[u].contains(&v) {
            return;
        }
        self.succ[u].push(v);
        self.pred[v].push(u);
        self.n_edges += 1;
    }

    /// Add an edge from every node in `us` to `v`.
    pub fn add_edges_from(&mut self, us: &[NodeId], v: NodeId) {
        for &u in us {
            self.add_edge(u, v);
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn n_edges(&self) -> usize {
        self.n_edges
    }

    pub fn node(&self, id: NodeId) -> &N {
        &self.nodes[id]
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut N {
        &mut self.nodes[id]
    }

    pub fn nodes(&self) -> impl Iterator<Item = (NodeId, &N)> {
        self.nodes.iter().enumerate()
    }

    pub fn successors(&self, id: NodeId) -> &[NodeId] {
        &self.succ[id]
    }

    pub fn predecessors(&self, id: NodeId) -> &[NodeId] {
        &self.pred[id]
    }

    pub fn has_edge(&self, u: NodeId, v: NodeId) -> bool {
        self.succ[u].contains(&v)
    }

    /// All edges in arbitrary order.
    pub fn edges(&self) -> Vec<(NodeId, NodeId)> {
        let mut out = Vec::with_capacity(self.n_edges);
        for (u, vs) in self.succ.iter().enumerate() {
            for &v in vs {
                out.push((u, v));
            }
        }
        out
    }

    pub fn in_degree(&self, id: NodeId) -> usize {
        self.pred[id].len()
    }

    pub fn out_degree(&self, id: NodeId) -> usize {
        self.succ[id].len()
    }

    /// Nodes with no predecessors.
    pub fn sources(&self) -> Vec<NodeId> {
        (0..self.n_nodes()).filter(|&v| self.pred[v].is_empty()).collect()
    }

    /// Nodes with no successors.
    pub fn sinks(&self) -> Vec<NodeId> {
        (0..self.n_nodes()).filter(|&v| self.succ[v].is_empty()).collect()
    }

    /// Rebuild this graph keeping the same nodes but only edges accepted by
    /// the predicate. Used to derive the MEG as a `Dag` sharing payload refs.
    pub fn filter_edges(&self, mut keep: impl FnMut(NodeId, NodeId) -> bool) -> Dag<()> {
        let mut g = Dag::with_capacity(self.n_nodes());
        for _ in 0..self.n_nodes() {
            g.add_node(());
        }
        for (u, v) in self.edges() {
            if keep(u, v) {
                g.add_edge(u, v);
            }
        }
        g
    }

    /// Structure-only copy (payloads dropped).
    pub fn structure(&self) -> Dag<()> {
        self.filter_edges(|_, _| true)
    }

    /// Full acyclicity + adjacency-consistency validation.
    pub fn validate(&self) -> Result<(), String> {
        // pred/succ mirror each other
        for (u, vs) in self.succ.iter().enumerate() {
            for &v in vs {
                if !self.pred[v].contains(&u) {
                    return Err(format!("edge ({u},{v}) missing from pred list"));
                }
            }
        }
        // acyclic
        crate::graph::topo::topo_order(self).map(|_| ()).map_err(|c| {
            format!("cycle detected through node {c}")
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Dag<&'static str> {
        // a -> b, a -> c, b -> d, c -> d
        let mut g = Dag::new();
        let a = g.add_node("a");
        let b = g.add_node("b");
        let c = g.add_node("c");
        let d = g.add_node("d");
        g.add_edge(a, b);
        g.add_edge(a, c);
        g.add_edge(b, d);
        g.add_edge(c, d);
        g
    }

    #[test]
    fn counts_and_adjacency() {
        let g = diamond();
        assert_eq!(g.n_nodes(), 4);
        assert_eq!(g.n_edges(), 4);
        assert_eq!(g.successors(0), &[1, 2]);
        assert_eq!(g.predecessors(3), &[1, 2]);
        assert!(g.has_edge(0, 1));
        assert!(!g.has_edge(1, 0));
    }

    #[test]
    fn duplicate_edges_ignored() {
        let mut g = diamond();
        g.add_edge(0, 1);
        assert_eq!(g.n_edges(), 4);
    }

    #[test]
    fn sources_and_sinks() {
        let g = diamond();
        assert_eq!(g.sources(), vec![0]);
        assert_eq!(g.sinks(), vec![3]);
    }

    #[test]
    fn edges_enumeration() {
        let g = diamond();
        let mut es = g.edges();
        es.sort_unstable();
        assert_eq!(es, vec![(0, 1), (0, 2), (1, 3), (2, 3)]);
    }

    #[test]
    #[should_panic(expected = "self-loop")]
    fn self_loop_panics() {
        let mut g: Dag<()> = Dag::new();
        let a = g.add_node(());
        g.add_edge(a, a);
    }

    #[test]
    fn validate_detects_cycle() {
        let mut g: Dag<()> = Dag::new();
        let a = g.add_node(());
        let b = g.add_node(());
        g.add_edge(a, b);
        assert!(g.validate().is_ok());
        g.add_edge(b, a);
        assert!(g.validate().is_err());
    }

    #[test]
    fn filter_edges_keeps_structure() {
        let g = diamond();
        let f = g.filter_edges(|u, _| u == 0);
        assert_eq!(f.n_nodes(), 4);
        assert_eq!(f.n_edges(), 2);
        assert!(f.has_edge(0, 1) && f.has_edge(0, 2) && !f.has_edge(1, 3));
    }
}
