//! Seeded random-DAG generators for property tests and micro-benchmarks.
//!
//! Two flavours: `random_dag` (Erdős–Rényi over a fixed topological order —
//! worst-case-ish structure) and `layered_dag` (NN-shaped: layers of parallel
//! branches joined by concat/add-like nodes, the structures Table 1 is about).

use super::dag::Dag;
use crate::util::Pcg32;

/// Erdős–Rényi DAG: nodes 0..n with each forward edge (i < j) present with
/// probability `p`. Always acyclic by construction.
pub fn random_dag(rng: &mut Pcg32, n: usize, p: f64) -> Dag<()> {
    let mut g = Dag::with_capacity(n);
    for _ in 0..n {
        g.add_node(());
    }
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.gen_bool(p) {
                g.add_edge(i, j);
            }
        }
    }
    g
}

/// A connected DAG shaped like a neural-network cell: a chain of "blocks",
/// each fanning out into `1..=max_branches` parallel branches of length
/// `1..=max_branch_len`, merged by a join node. Mirrors the inception/NAS
/// cell topologies whose logical concurrency Table 1 reports.
pub fn layered_dag(
    rng: &mut Pcg32,
    n_blocks: usize,
    max_branches: usize,
    max_branch_len: usize,
) -> Dag<()> {
    let mut g = Dag::new();
    let mut prev = g.add_node(()); // stem
    for _ in 0..n_blocks {
        let branches = rng.gen_range_inclusive(1, max_branches.max(1));
        let mut outs = Vec::with_capacity(branches);
        for _ in 0..branches {
            let len = rng.gen_range_inclusive(1, max_branch_len.max(1));
            let mut cur = prev;
            for _ in 0..len {
                let nxt = g.add_node(());
                g.add_edge(cur, nxt);
                cur = nxt;
            }
            outs.push(cur);
        }
        let join = g.add_node(());
        for o in outs {
            g.add_edge(o, join);
        }
        prev = join;
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topo::topo_order;

    #[test]
    fn random_dag_is_acyclic_and_sized() {
        let mut rng = Pcg32::new(1);
        for _ in 0..10 {
            let g = random_dag(&mut rng, 50, 0.1);
            assert_eq!(g.n_nodes(), 50);
            assert!(topo_order(&g).is_ok());
        }
    }

    #[test]
    fn density_scales_with_p() {
        let mut rng = Pcg32::new(2);
        let sparse = random_dag(&mut rng, 60, 0.02);
        let dense = random_dag(&mut rng, 60, 0.5);
        assert!(sparse.n_edges() < dense.n_edges());
    }

    #[test]
    fn layered_dag_single_source_single_sink() {
        let mut rng = Pcg32::new(3);
        for _ in 0..10 {
            let g = layered_dag(&mut rng, 4, 5, 3);
            assert!(topo_order(&g).is_ok());
            assert_eq!(g.sources().len(), 1);
            assert_eq!(g.sinks().len(), 1);
        }
    }

    #[test]
    fn layered_dag_reproducible() {
        let a = layered_dag(&mut Pcg32::new(42), 3, 4, 2);
        let b = layered_dag(&mut Pcg32::new(42), 3, 4, 2);
        assert_eq!(a.n_nodes(), b.n_nodes());
        let (mut ea, mut eb) = (a.edges(), b.edges());
        ea.sort_unstable();
        eb.sort_unstable();
        assert_eq!(ea, eb);
    }
}
