//! Reachability / transitive closure as a bitset matrix.
//!
//! Both pillars of the paper's Algorithm 1 consume reachability: the MEG
//! (Step 1) needs it to find redundant edges, and the max-logical-concurrency
//! verifier needs "is there a path between u and v in either direction". The
//! closure is computed once per graph in O(V·E/64) by propagating bit rows in
//! reverse topological order.

use super::dag::{Dag, NodeId};
use super::topo::topo_order;

/// Transitive closure of a DAG. `reaches(u, v)` is true iff a path of length
/// ≥ 1 exists from `u` to `v` (a node does not reach itself).
#[derive(Debug, Clone)]
pub struct Reachability {
    n: usize,
    words: usize,
    bits: Vec<u64>, // row-major: node u owns bits[u*words .. (u+1)*words]
}

impl Reachability {
    pub fn compute<N>(g: &Dag<N>) -> Self {
        let n = g.n_nodes();
        let words = n.div_ceil(64);
        let mut bits = vec![0u64; n * words];
        let order = topo_order(g).expect("reachability requires a DAG");
        // Reverse topo: successors' rows are final when we process a node.
        for &u in order.iter().rev() {
            // Split borrows: copy successor rows into u's row.
            for &v in g.successors(u) {
                let (urow_start, vrow_start) = (u * words, v * words);
                // set bit v
                bits[urow_start + v / 64] |= 1u64 << (v % 64);
                // OR in v's row
                if urow_start != vrow_start {
                    let (lo, hi) = if urow_start < vrow_start {
                        let (a, b) = bits.split_at_mut(vrow_start);
                        (&mut a[urow_start..urow_start + words], &b[..words])
                    } else {
                        let (a, b) = bits.split_at_mut(urow_start);
                        (&mut b[..words], &a[vrow_start..vrow_start + words])
                    };
                    for (x, y) in lo.iter_mut().zip(hi.iter()) {
                        *x |= *y;
                    }
                }
            }
        }
        Reachability { n, words, bits }
    }

    #[inline]
    pub fn reaches(&self, u: NodeId, v: NodeId) -> bool {
        debug_assert!(u < self.n && v < self.n);
        self.bits[u * self.words + v / 64] >> (v % 64) & 1 == 1
    }

    /// True iff `u` and `v` are comparable (a path exists in either direction).
    #[inline]
    pub fn comparable(&self, u: NodeId, v: NodeId) -> bool {
        self.reaches(u, v) || self.reaches(v, u)
    }

    /// True iff `u` and `v` are logically concurrent (independent) — the
    /// relation at the heart of "maximum logical concurrency".
    #[inline]
    pub fn independent(&self, u: NodeId, v: NodeId) -> bool {
        u != v && !self.comparable(u, v)
    }

    /// Number of nodes reachable from `u`.
    pub fn count_from(&self, u: NodeId) -> usize {
        self.bits[u * self.words..(u + 1) * self.words]
            .iter()
            .map(|w| w.count_ones() as usize)
            .sum()
    }

    /// All edges of the transitive closure, as (u, v) pairs. Iterates set
    /// bits word-by-word with `trailing_zeros` (O(V²/64 + |closure|))
    /// instead of probing all V² bits, and pre-sizes the output from the
    /// exact popcount.
    pub fn closure_edges(&self) -> Vec<(NodeId, NodeId)> {
        let total: usize = self.bits.iter().map(|w| w.count_ones() as usize).sum();
        let mut out = Vec::with_capacity(total);
        for u in 0..self.n {
            let row = &self.bits[u * self.words..(u + 1) * self.words];
            for (wi, &word) in row.iter().enumerate() {
                let mut rest = word;
                while rest != 0 {
                    let bit = rest.trailing_zeros() as usize;
                    out.push((u, wi * 64 + bit));
                    rest &= rest - 1;
                }
            }
        }
        out
    }

    pub fn n_nodes(&self) -> usize {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::random_dag;
    use crate::util::Pcg32;

    #[test]
    fn chain_reachability() {
        let mut g: Dag<()> = Dag::new();
        for _ in 0..4 {
            g.add_node(());
        }
        g.add_edge(0, 1);
        g.add_edge(1, 2);
        g.add_edge(2, 3);
        let r = Reachability::compute(&g);
        assert!(r.reaches(0, 3));
        assert!(r.reaches(1, 3));
        assert!(!r.reaches(3, 0));
        assert!(!r.reaches(0, 0), "no self reachability without a cycle");
        assert_eq!(r.count_from(0), 3);
    }

    #[test]
    fn diamond_independence() {
        let mut g: Dag<()> = Dag::new();
        for _ in 0..4 {
            g.add_node(());
        }
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        let r = Reachability::compute(&g);
        assert!(r.independent(1, 2));
        assert!(!r.independent(0, 3));
        assert!(r.comparable(0, 3));
    }

    #[test]
    fn matches_dfs_on_random_graphs() {
        // Cross-check the bitset closure against a simple per-node DFS.
        let mut rng = Pcg32::new(0xDA6);
        for _ in 0..20 {
            let g = random_dag(&mut rng, 40, 0.1);
            let r = Reachability::compute(&g);
            for u in 0..g.n_nodes() {
                let mut seen = vec![false; g.n_nodes()];
                let mut stack = vec![u];
                while let Some(x) = stack.pop() {
                    for &w in g.successors(x) {
                        if !seen[w] {
                            seen[w] = true;
                            stack.push(w);
                        }
                    }
                }
                for v in 0..g.n_nodes() {
                    assert_eq!(r.reaches(u, v), seen[v], "u={u} v={v}");
                }
            }
        }
    }

    #[test]
    fn closure_edges_match_reaches_bit_probing() {
        let mut rng = Pcg32::new(0xED6E5);
        for n in [3usize, 40, 70, 130] {
            let g = random_dag(&mut rng, n, 0.08);
            let r = Reachability::compute(&g);
            let edges = r.closure_edges();
            let mut expected = Vec::new();
            for u in 0..n {
                for v in 0..n {
                    if r.reaches(u, v) {
                        expected.push((u, v));
                    }
                }
            }
            assert_eq!(edges, expected);
        }
    }

    #[test]
    fn works_past_64_nodes() {
        // exercise multi-word rows
        let mut g: Dag<()> = Dag::new();
        for _ in 0..130 {
            g.add_node(());
        }
        for i in 0..129 {
            g.add_edge(i, i + 1);
        }
        let r = Reachability::compute(&g);
        assert!(r.reaches(0, 129));
        assert_eq!(r.count_from(0), 129);
        assert_eq!(r.count_from(129), 0);
    }
}
