//! Topological ordering (Kahn's algorithm) with deterministic tie-breaking.
//!
//! Determinism matters twice: the AoT pre-run submits tasks in this order, so
//! the recorded task schedule must be reproducible; and the paper's replay
//! correctness argument relies on same-stream tasks being submitted in a
//! topological order (stream FIFO then guarantees intra-stream dependencies).

use super::dag::{Dag, NodeId};

/// Kahn topological sort. Ties are broken by smallest node id, making the
/// order a deterministic function of the graph. Returns `Err(node)` with a
/// node on a cycle if the graph is cyclic.
pub fn topo_order<N>(g: &Dag<N>) -> Result<Vec<NodeId>, NodeId> {
    let n = g.n_nodes();
    let mut indeg: Vec<usize> = (0..n).map(|v| g.in_degree(v)).collect();
    // Min-heap via BinaryHeap<Reverse<..>> for deterministic smallest-id-first.
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut heap: BinaryHeap<Reverse<NodeId>> =
        (0..n).filter(|&v| indeg[v] == 0).map(Reverse).collect();
    let mut order = Vec::with_capacity(n);
    while let Some(Reverse(v)) = heap.pop() {
        order.push(v);
        for &w in g.successors(v) {
            indeg[w] -= 1;
            if indeg[w] == 0 {
                heap.push(Reverse(w));
            }
        }
    }
    if order.len() == n {
        Ok(order)
    } else {
        // Some node still has positive in-degree: it is on or behind a cycle.
        Err((0..n).find(|&v| indeg[v] > 0).expect("cycle implies leftover node"))
    }
}

/// Position of each node in the topological order (inverse permutation).
pub fn topo_positions<N>(g: &Dag<N>) -> Result<Vec<usize>, NodeId> {
    let order = topo_order(g)?;
    let mut pos = vec![0usize; g.n_nodes()];
    for (i, &v) in order.iter().enumerate() {
        pos[v] = i;
    }
    Ok(pos)
}

/// Longest path lengths (in edges) from any source, per node. Used for
/// layered layout and as a quick lower bound on the critical path.
pub fn depths<N>(g: &Dag<N>) -> Vec<usize> {
    let order = topo_order(g).expect("depths requires acyclic graph");
    let mut depth = vec![0usize; g.n_nodes()];
    for &v in &order {
        for &w in g.successors(v) {
            depth[w] = depth[w].max(depth[v] + 1);
        }
    }
    depth
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_edges() {
        let mut g: Dag<()> = Dag::new();
        for _ in 0..5 {
            g.add_node(());
        }
        g.add_edge(3, 1);
        g.add_edge(1, 4);
        g.add_edge(0, 2);
        let order = topo_order(&g).unwrap();
        let pos = |v| order.iter().position(|&x| x == v).unwrap();
        assert!(pos(3) < pos(1));
        assert!(pos(1) < pos(4));
        assert!(pos(0) < pos(2));
    }

    #[test]
    fn deterministic_smallest_first() {
        let mut g: Dag<()> = Dag::new();
        for _ in 0..4 {
            g.add_node(());
        }
        // no edges: order must be by id
        assert_eq!(topo_order(&g).unwrap(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn cycle_reported() {
        let mut g: Dag<()> = Dag::new();
        let a = g.add_node(());
        let b = g.add_node(());
        let c = g.add_node(());
        g.add_edge(a, b);
        g.add_edge(b, c);
        g.add_edge(c, a);
        assert!(topo_order(&g).is_err());
    }

    #[test]
    fn positions_are_inverse() {
        let mut g: Dag<()> = Dag::new();
        for _ in 0..6 {
            g.add_node(());
        }
        g.add_edge(5, 0);
        g.add_edge(0, 3);
        let order = topo_order(&g).unwrap();
        let pos = topo_positions(&g).unwrap();
        for (i, &v) in order.iter().enumerate() {
            assert_eq!(pos[v], i);
        }
    }

    #[test]
    fn depths_of_chain() {
        let mut g: Dag<()> = Dag::new();
        for _ in 0..4 {
            g.add_node(());
        }
        g.add_edge(0, 1);
        g.add_edge(1, 2);
        g.add_edge(2, 3);
        assert_eq!(depths(&g), vec![0, 1, 2, 3]);
    }
}
